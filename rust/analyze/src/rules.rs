//! The rule engine.  Every rule is a lexical/structural approximation
//! (see module docs in `lexer.rs`, `resolve.rs`, `callgraph.rs`); each
//! one documents the exact pattern it matches so a surprising report can
//! be traced.
//!
//! Per-file rules: R1 `no-unwrap`, R5 `panic-isolation`,
//! `unsafe-comment`.  Whole-crate rules (item graph + call graph):
//! R2 `send-hygiene`, R4 `wire-drift`/`wire-dead`, R7 `lock-order`,
//! R8 `thread-escape`, R9 `stamp-discipline`.

use std::collections::{BTreeMap, HashMap, HashSet};

use crate::callgraph::CallGraph;
use crate::lexer::{Kind, Tok};
use crate::resolve::{brace_pairs, tx, ItemGraph};
use crate::{SourceFile, Violation};

/// Fused-path modules: the code where a panic kills a worker cycle and a
/// stale page aliases another session's KV.  `kvcache/props.rs` is a
/// test-only oracle suite (its own file, so `#[cfg(test)]` stripping
/// can't see the `mod` wrapper in `kvcache/mod.rs`) and is exempt.
fn is_fused_path(p: &str) -> bool {
    (p.contains("scheduler/") || p.ends_with("engine/sessions.rs") || p.contains("kvcache/"))
        && !p.ends_with("kvcache/props.rs")
}

/// Files that parse or emit wire-protocol JSON keys.
fn is_wire_file(p: &str) -> bool {
    p.ends_with("server/mod.rs") || p.ends_with("main.rs")
}

/// Files whose `("key", Json...)` tuples are the server's emitted wire
/// surface (main.rs is excluded: its `("flag", default)` tuples are CLI
/// argument lookups, not protocol emissions).
fn is_wire_emit_file(p: &str) -> bool {
    p.ends_with("server/mod.rs") || p.ends_with("scheduler/mod.rs")
}

/// Files that spawn worker / pump threads.
fn is_thread_file(p: &str) -> bool {
    p.ends_with("scheduler/mod.rs") || p.ends_with("server/mod.rs")
}

/// Shared whole-crate context every interprocedural rule queries.
pub struct Analysis<'a> {
    pub files: &'a [SourceFile],
    pub items: ItemGraph,
    pub cg: CallGraph,
}

impl<'a> Analysis<'a> {
    pub fn build(files: &'a [SourceFile]) -> Analysis<'a> {
        let items = ItemGraph::build(files);
        let cg = CallGraph::build(files, &items);
        Analysis { files, items, cg }
    }

    fn path(&self, file: usize) -> &str {
        &self.files[file].path
    }

    /// Frame label for a call edge: `file:line: caller -> callee`.
    fn call_frame(&self, caller: usize, line: usize, callee: usize) -> String {
        format!(
            "{}:{}: {} -> {}",
            self.path(self.items.fns[caller].file),
            line,
            self.items.fns[caller].qname(),
            self.items.fns[callee].qname()
        )
    }
}

pub fn check_crate(files: &[SourceFile]) -> Vec<Violation> {
    let a = Analysis::build(files);
    let mut out: Vec<Violation> = Vec::new();
    for f in files {
        r1_no_unwrap(f, &mut out);
        r5_panic_isolation(f, &mut out);
        r_unsafe_comment(f, &mut out);
    }
    r2_send_hygiene(&a, &mut out);
    r4_wire_drift(&a, &mut out);
    r4_wire_dead(&a, &mut out);
    r7_lock_order(&a, &mut out);
    r8_thread_escape(&a, &mut out);
    r9_stamp_discipline(&a, &mut out);
    out
}

fn viol(f: &SourceFile, line: usize, rule: &str, msg: String) -> Violation {
    Violation {
        file: f.path.clone(),
        line,
        rule: rule.to_string(),
        severity: "error".to_string(),
        msg,
        witness: Vec::new(),
    }
}

// ---------------------------------------------------------------------
// R1 `no-unwrap`
// ---------------------------------------------------------------------
// Pattern: `.unwrap(` / `.expect(` (exact identifier, so `unwrap_or_else`
// and friends are untouched), plus `)[` — indexing straight into a call
// result, where no named binding carries a length proof.  Fused-path
// files only; other indexing (named slices, tensors) is handled by the
// shadow sanitizer at runtime, not lexically.

fn r1_no_unwrap(f: &SourceFile, out: &mut Vec<Violation>) {
    if !is_fused_path(&f.path) {
        return;
    }
    let t = &f.toks;
    for i in 0..t.len() {
        if t[i].kind == Kind::Ident
            && (t[i].text == "unwrap" || t[i].text == "expect")
            && tx(t, i.wrapping_sub(1)) == "."
            && tx(t, i + 1) == "("
            && !f.allowed("no-unwrap", t[i].line)
        {
            out.push(viol(
                f,
                t[i].line,
                "no-unwrap",
                format!(
                    ".{}() on the fused path — a panic here kills a worker cycle; \
                     return through the existing Result plumbing or annotate with \
                     `hass-lint: allow(no-unwrap)`",
                    t[i].text
                ),
            ));
        }
        if t[i].text == ")" && tx(t, i + 1) == "[" && !f.allowed("no-unwrap", t[i].line) {
            out.push(viol(
                f,
                t[i].line,
                "no-unwrap",
                "indexing straight into a call result on the fused path — bind it and \
                 bounds-check, or annotate with `hass-lint: allow(no-unwrap)`"
                    .to_string(),
            ));
        }
    }
}

// ---------------------------------------------------------------------
// R2 `send-hygiene` — alias-aware type-graph reachability
// ---------------------------------------------------------------------
// Thread-crossing roots are type names inside `Arc<...>` / `Sender<...>`
// / `SyncSender<...>` / `Receiver<...>` generics, `channel::<T>` /
// `sync_channel::<T>` turbofish, and `Arc::new(...)` construction.  From
// those roots the rule walks struct/enum/type-alias field types
// transitively (resolving each field ident through the defining file's
// `use` aliases, so `Shared<u32>` with `use std::rc::Rc as Shared` is
// caught) and flags any `Rc`/`Cell`/`RefCell`/`UnsafeCell` it reaches,
// with the field-chain witness from the root.

const NON_SEND: [&str; 4] = ["Rc", "Cell", "RefCell", "UnsafeCell"];

/// Is this canonical path a std non-Send core type?  Bare names count
/// (fully-qualified uses lex as a bare final ident with no alias), but a
/// crate-local path like `crate::foo::Cell` does not.
fn non_send_core(canon: &str) -> Option<&str> {
    let last = canon.rsplit("::").next().unwrap_or(canon);
    if !NON_SEND.contains(&last) {
        return None;
    }
    if canon == last
        || canon.starts_with("std::")
        || canon.starts_with("core::")
        || canon.starts_with("alloc::")
    {
        Some(last)
    } else {
        None
    }
}

/// Tainted types: type name -> witness frames ending at a non-Send core.
fn type_taint(a: &Analysis) -> HashMap<String, Vec<String>> {
    let mut taint: HashMap<String, Vec<String>> = HashMap::new();
    loop {
        let mut add: Vec<(String, Vec<String>)> = Vec::new();
        for (name, ti) in &a.items.types {
            if taint.contains_key(name) {
                continue;
            }
            for (fid, line) in &ti.fields {
                if let Some(core) = non_send_core(a.items.canon(ti.file, fid)) {
                    add.push((
                        name.clone(),
                        vec![format!("{}:{}: {} holds non-Send `{}`", a.path(ti.file), line, name, core)],
                    ));
                    break;
                }
                if let Some(chain) = taint.get(fid) {
                    let mut w =
                        vec![format!("{}:{}: {} embeds {}", a.path(ti.file), line, name, fid)];
                    w.extend(chain.iter().cloned());
                    add.push((name.clone(), w));
                    break;
                }
            }
        }
        if add.is_empty() {
            return taint;
        }
        taint.extend(add);
    }
}

/// Identifiers inside the generic argument list opening at `t[open]`
/// (which must be `<`).  Bounded walk; `->` return arrows don't close.
fn generic_idents(t: &[Tok], open: usize, roots: &mut HashSet<String>) {
    let mut d = 0i64;
    let mut j = open;
    let mut budget = 96usize;
    while j < t.len() && budget > 0 {
        budget -= 1;
        match tx(t, j) {
            "<" => d += 1,
            ">" => {
                if j == 0 || tx(t, j - 1) != "-" {
                    d -= 1;
                    if d == 0 {
                        break;
                    }
                }
            }
            _ => {
                if t[j].kind == Kind::Ident {
                    roots.insert(t[j].text.clone());
                }
            }
        }
        j += 1;
    }
}

fn collect_roots(a: &Analysis) -> HashSet<String> {
    let mut roots: HashSet<String> = HashSet::new();
    for f in a.files {
        let t = &f.toks;
        for i in 0..t.len() {
            if t[i].kind != Kind::Ident {
                continue;
            }
            let name = t[i].text.as_str();
            if matches!(name, "Arc" | "Sender" | "SyncSender" | "Receiver") && tx(t, i + 1) == "<"
            {
                generic_idents(t, i + 1, &mut roots);
            }
            if matches!(name, "channel" | "sync_channel") {
                // turbofish: channel::<T>(...)
                for j in (i + 1)..(i + 5).min(t.len()) {
                    if tx(t, j) == "<" {
                        generic_idents(t, j, &mut roots);
                        break;
                    }
                    if tx(t, j) != ":" {
                        break;
                    }
                }
            }
            if name == "Arc"
                && tx(t, i + 1) == ":"
                && tx(t, i + 2) == ":"
                && tx(t, i + 3) == "new"
                && tx(t, i + 4) == "("
            {
                let mut d = 0i64;
                let mut j = i + 4;
                while j < t.len() {
                    match tx(t, j) {
                        "(" => d += 1,
                        ")" => {
                            d -= 1;
                            if d == 0 {
                                break;
                            }
                        }
                        _ => {
                            if t[j].kind == Kind::Ident && a.items.types.contains_key(&t[j].text) {
                                roots.insert(t[j].text.clone());
                            }
                        }
                    }
                    j += 1;
                }
            }
        }
    }
    roots
}

fn r2_send_hygiene(a: &Analysis, out: &mut Vec<Violation>) {
    let taint = type_taint(a);
    // BFS from the roots over the type graph, tracking the field chain
    let mut queue: Vec<(String, Vec<String>)> =
        collect_roots(a).into_iter().map(|r| (r, Vec::new())).collect();
    queue.sort();
    let mut seen: HashSet<String> = queue.iter().map(|(n, _)| n.clone()).collect();
    while let Some((name, chain)) = queue.pop() {
        let Some(ti) = a.items.types.get(&name) else { continue };
        let f = &a.files[ti.file];
        for (id, line) in &ti.fields {
            if let Some(core) = non_send_core(a.items.canon(ti.file, id)) {
                if !f.allowed("send-hygiene", *line) {
                    let mut v = viol(
                        f,
                        *line,
                        "send-hygiene",
                        format!(
                            "`{name}` holds non-Send `{core}` but is reachable from an \
                             Arc/channel thread boundary — the Arc page-pool migration \
                             gate; move the state or annotate with \
                             `hass-lint: allow(send-hygiene)`"
                        ),
                    );
                    v.witness = chain.clone();
                    out.push(v);
                }
            } else if a.items.types.contains_key(id)
                && taint.contains_key(id)
                && seen.insert(id.clone())
            {
                let mut c = chain.clone();
                c.push(format!("{}:{}: {} embeds {}", a.path(ti.file), line, name, id));
                queue.push((id.clone(), c));
            }
        }
    }
}

// ---------------------------------------------------------------------
// R4 `wire-drift` / `wire-dead`
// ---------------------------------------------------------------------
// EMIT keys: `("key",` tuple patterns in server/scheduler/main (the
// Json::obj builder convention) plus `"key":` sequences embedded inside
// string literals (raw request lines like `{"stats":true}`).  READ keys:
// `.get("key")` / `.str_at("key")` / `.usize_at` / `.f64_at` / `.u64_at`
// / `.bool_at`, plus calls through key-reader helper fns (a fn that
// forwards a `&str` parameter into one of those accessors: each string
// literal passed at a call site counts as a read of that key).
//
// Forward (`wire-drift`): every key READ in a wire file must be EMITTED
// somewhere, else the client parses a key the server no longer sends.
// Reverse (`wire-dead`, warning): every `("key", Json...)` tuple emitted
// by server/scheduler must be READ somewhere in the crate (tests
// included — the unstripped token stream is scanned), else the key is
// dead protocol surface.

fn embedded_keys(content: &str, keys: &mut HashSet<String>) {
    let b: Vec<char> = content.chars().collect();
    let mut i = 0usize;
    while i < b.len() {
        if b[i] == '"' || (b[i] == '\\' && i + 1 < b.len() && b[i + 1] == '"') {
            let mut j = if b[i] == '"' { i + 1 } else { i + 2 };
            let start = j;
            while j < b.len() && (b[j].is_alphanumeric() || b[j] == '_') {
                j += 1;
            }
            if j > start {
                // closing quote (possibly escaped) then ':'
                let mut k = j;
                if k < b.len() && b[k] == '\\' {
                    k += 1;
                }
                if k < b.len() && b[k] == '"' {
                    k += 1;
                    if k < b.len() && b[k] == ':' {
                        keys.insert(b[start..j].iter().collect());
                        i = k;
                        continue;
                    }
                }
            }
            i = j.max(i + 1);
            continue;
        }
        i += 1;
    }
}

const READ_FNS: [&str; 6] = ["get", "str_at", "usize_at", "f64_at", "u64_at", "bool_at"];

/// Fns that forward a `&str` parameter into a READ_FN — calls to them
/// with a string literal count as reads of that key.  Restricted to fns
/// that visibly handle `Json` (a `Json`-typed parameter or an
/// `impl Json` method): without that gate, generic string-keyed lookups
/// like `Args::get_or` would turn every CLI flag into a "wire key".
fn key_reader_fns(a: &Analysis) -> HashSet<usize> {
    let mut readers: HashSet<usize> = HashSet::new();
    for (fi, f) in a.items.fns.iter().enumerate() {
        let Some((open, close)) = f.body else { continue };
        let touches_json = f.impl_target.as_deref() == Some("Json")
            || f.params.iter().any(|(_, tys)| tys.iter().any(|t| t == "Json"));
        if !touches_json {
            continue;
        }
        let str_params: Vec<&str> = f
            .params
            .iter()
            .filter(|(_, tys)| tys.iter().any(|t| t == "str" || t == "String"))
            .map(|(n, _)| n.as_str())
            .collect();
        if str_params.is_empty() {
            continue;
        }
        let t = &a.files[f.file].toks;
        for i in (open + 1)..close {
            if t[i].kind == Kind::Ident
                && READ_FNS.contains(&t[i].text.as_str())
                && tx(t, i + 1) == "("
                && str_params.contains(&tx(t, i + 2))
                && (tx(t, i + 3) == ")" || tx(t, i + 3) == ",")
            {
                readers.insert(fi);
                break;
            }
        }
    }
    readers
}

/// String literals at argument position (paren depth 1) of call sites to
/// any fn in `readers`, scanned over `t`; yields (key, line).
fn helper_read_keys(t: &[Tok], a: &Analysis, readers: &HashSet<usize>) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    for i in 0..t.len() {
        if t[i].kind != Kind::Ident || tx(t, i + 1) != "(" {
            continue;
        }
        let Some(cands) = a.items.by_name.get(&t[i].text) else { continue };
        if !cands.iter().any(|c| readers.contains(c)) {
            continue;
        }
        let mut d = 0i64;
        let mut j = i + 1;
        while j < t.len() {
            match tx(t, j) {
                "(" => d += 1,
                ")" => {
                    d -= 1;
                    if d == 0 {
                        break;
                    }
                }
                _ => {
                    if d == 1 && t[j].kind == Kind::Str {
                        out.push((t[j].text.clone(), t[j].line));
                    }
                }
            }
            j += 1;
        }
    }
    out
}

fn r4_wire_drift(a: &Analysis, out: &mut Vec<Violation>) {
    let readers = key_reader_fns(a);
    let mut emitted: HashSet<String> = HashSet::new();
    for f in a.files {
        if !(is_wire_file(&f.path) || f.path.ends_with("scheduler/mod.rs")) {
            continue;
        }
        let t = &f.toks;
        for i in 0..t.len() {
            if tx(t, i) == "("
                && t.get(i + 1).map(|k| k.kind == Kind::Str).unwrap_or(false)
                && tx(t, i + 2) == ","
            {
                emitted.insert(t[i + 1].text.clone());
            }
            if t[i].kind == Kind::Str {
                embedded_keys(&t[i].text, &mut emitted);
            }
        }
    }
    for f in a.files {
        if !is_wire_file(&f.path) {
            continue;
        }
        let t = &f.toks;
        for i in 0..t.len() {
            if t[i].kind == Kind::Ident
                && READ_FNS.contains(&t[i].text.as_str())
                && tx(t, i.wrapping_sub(1)) == "."
                && tx(t, i + 1) == "("
                && t.get(i + 2).map(|k| k.kind == Kind::Str).unwrap_or(false)
                && tx(t, i + 3) == ")"
            {
                let key = &t[i + 2].text;
                if !emitted.contains(key) && !f.allowed("wire-drift", t[i].line) {
                    out.push(viol(
                        f,
                        t[i].line,
                        "wire-drift",
                        format!(
                            "wire key \"{key}\" is parsed here but never emitted by \
                             server/scheduler — protocol drift"
                        ),
                    ));
                }
            }
        }
        // reads routed through key-reader helper fns
        for (key, line) in helper_read_keys(t, a, &readers) {
            if !emitted.contains(&key) && !f.allowed("wire-drift", line) {
                out.push(viol(
                    f,
                    line,
                    "wire-drift",
                    format!(
                        "wire key \"{key}\" is read through a key-reader helper but \
                         never emitted by server/scheduler — protocol drift"
                    ),
                ));
            }
        }
    }
}

fn r4_wire_dead(a: &Analysis, out: &mut Vec<Violation>) {
    let readers = key_reader_fns(a);
    // reads anywhere in the crate, tests included (toks_full)
    let mut read: HashSet<String> = HashSet::new();
    for f in a.files {
        let t = &f.toks_full;
        for i in 0..t.len() {
            if t[i].kind == Kind::Ident
                && READ_FNS.contains(&t[i].text.as_str())
                && tx(t, i.wrapping_sub(1)) == "."
                && tx(t, i + 1) == "("
                && t.get(i + 2).map(|k| k.kind == Kind::Str).unwrap_or(false)
            {
                read.insert(t[i + 2].text.clone());
            }
        }
        for (key, _) in helper_read_keys(t, a, &readers) {
            read.insert(key);
        }
    }
    // `("key", Json...)` emit tuples in the server/scheduler wire surface
    let mut seen: HashSet<String> = HashSet::new();
    for f in a.files {
        if !is_wire_emit_file(&f.path) {
            continue;
        }
        let t = &f.toks;
        for i in 0..t.len() {
            if tx(t, i) == "("
                && t.get(i + 1).map(|k| k.kind == Kind::Str).unwrap_or(false)
                && tx(t, i + 2) == ","
                && tx(t, i + 3) == "Json"
            {
                let key = &t[i + 1].text;
                if read.contains(key) || !seen.insert(key.clone()) {
                    continue;
                }
                let line = t[i + 1].line;
                if !f.allowed("wire-dead", line) {
                    let mut v = viol(
                        f,
                        line,
                        "wire-dead",
                        format!(
                            "wire key \"{key}\" is emitted but no reader in the crate \
                             consumes it — dead protocol surface"
                        ),
                    );
                    v.severity = "warning".to_string();
                    out.push(v);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// R5 `panic-isolation`
// ---------------------------------------------------------------------
// Every `spawn(...)` argument span in scheduler/server must mention
// `catch_unwind`: a worker or writer-pump thread that panics bare takes
// its queue down silently.

fn r5_panic_isolation(f: &SourceFile, out: &mut Vec<Violation>) {
    if !is_thread_file(&f.path) {
        return;
    }
    let t = &f.toks;
    for i in 0..t.len() {
        if t[i].kind != Kind::Ident || t[i].text != "spawn" || tx(t, i + 1) != "(" {
            continue;
        }
        let mut d = 0i64;
        let mut j = i + 1;
        let mut has_catch = false;
        while j < t.len() {
            match tx(t, j) {
                "(" => d += 1,
                ")" => {
                    d -= 1;
                    if d == 0 {
                        break;
                    }
                }
                "catch_unwind" => has_catch = true,
                _ => {}
            }
            j += 1;
        }
        if !has_catch && !f.allowed("panic-isolation", t[i].line) {
            out.push(viol(
                f,
                t[i].line,
                "panic-isolation",
                "spawned thread body lacks catch_unwind — a panic here silently kills \
                 the worker/pump loop"
                    .to_string(),
            ));
        }
    }
}

// ---------------------------------------------------------------------
// R-unsafe `unsafe-comment`
// ---------------------------------------------------------------------
// Every `unsafe` token needs a comment containing `SAFETY:` on the same
// line or within the 3 lines above.

fn r_unsafe_comment(f: &SourceFile, out: &mut Vec<Violation>) {
    for tok in f.toks.iter().filter(|t| t.kind == Kind::Ident && t.text == "unsafe") {
        let line = tok.line;
        let documented = f
            .comments
            .iter()
            .any(|c| c.text.contains("SAFETY:") && c.line <= line && c.line + 3 >= line);
        if !documented && !f.allowed("unsafe-comment", line) {
            out.push(viol(
                f,
                line,
                "unsafe-comment",
                "unsafe block without a `// SAFETY:` comment in the preceding 3 lines"
                    .to_string(),
            ));
        }
    }
}

// ---------------------------------------------------------------------
// R7 `lock-order` — static acquisition-order cycles
// ---------------------------------------------------------------------
// Acquisition sites are `trace(... CLASS)` calls where CLASS is the last
// SCREAMING_CASE identifier in the argument list (`util::lockorder`'s
// RAII convention: `let _t = lockorder::trace(lockorder::STATS);`).  A
// token held in a lexical scope covers every later acquisition in that
// scope and — through bottom-up call-graph summaries — every class any
// callee invoked in that scope can acquire.  Cycles in the resulting
// class digraph (including self-loops: same-class nesting) are reported
// once per cycle with a full witness call chain for every edge.  This is
// the static complement of the `HASS_CHECK=1` runtime inversion
// detector: it covers schedules the tests never run, at the cost of
// ignoring liveness (an early `drop(token)` still counts as held to the
// end of the lexical scope) and closure indirection (a closure invoked
// while a lock is held is not an edge).

struct Acq {
    class: String,
    tok: usize,
    line: usize,
    scope_end: usize,
}

fn screaming(s: &str) -> bool {
    s.len() >= 2
        && s.chars().all(|c| c.is_ascii_uppercase() || c.is_ascii_digit() || c == '_')
        && s.chars().any(|c| c.is_ascii_uppercase())
}

fn r7_lock_order(a: &Analysis, out: &mut Vec<Violation>) {
    let nfns = a.items.fns.len();
    let mut local: Vec<Vec<Acq>> = Vec::with_capacity(nfns);
    for f in &a.items.fns {
        let mut acqs: Vec<Acq> = Vec::new();
        if let Some((open, close)) = f.body {
            let t = &a.files[f.file].toks;
            let pairs = brace_pairs(t);
            for i in (open + 1)..close {
                if t[i].kind != Kind::Ident || t[i].text != "trace" || tx(t, i + 1) != "(" {
                    continue;
                }
                let mut d = 0i64;
                let mut j = i + 1;
                let mut class: Option<String> = None;
                while j < t.len() {
                    match tx(t, j) {
                        "(" => d += 1,
                        ")" => {
                            d -= 1;
                            if d == 0 {
                                break;
                            }
                        }
                        _ => {
                            if t[j].kind == Kind::Ident && screaming(&t[j].text) {
                                class = Some(t[j].text.clone());
                            }
                        }
                    }
                    j += 1;
                }
                let Some(class) = class else { continue };
                // innermost enclosing block: the token's lexical scope
                let scope_end = pairs
                    .iter()
                    .filter(|(&o, &c)| o >= open && o < i && i < c)
                    .max_by_key(|(&o, _)| o)
                    .map(|(_, &c)| c)
                    .unwrap_or(close);
                acqs.push(Acq { class, tok: i, line: t[i].line, scope_end });
            }
        }
        local.push(acqs);
    }
    // bottom-up: every class a fn can acquire anywhere in its call tree
    let local_sets: Vec<HashSet<String>> =
        local.iter().map(|v| v.iter().map(|a| a.class.clone()).collect()).collect();
    let all = a.cg.propagate_sets(&local_sets);
    // per-class next-hop tables toward a local acquirer (for witnesses)
    let classes: HashSet<String> = local_sets.iter().flatten().cloned().collect();
    let mut hops_for: HashMap<String, HashMap<usize, Option<crate::callgraph::CallSite>>> =
        HashMap::new();
    for c in &classes {
        let targets: HashSet<usize> =
            (0..nfns).filter(|&f| local_sets[f].contains(c)).collect();
        hops_for.insert(c.clone(), a.cg.next_hops(&targets));
    }
    let acq_frame = |f: usize, acq: &Acq| {
        format!(
            "{}:{}: {} acquires {}",
            a.path(a.items.fns[f].file),
            acq.line,
            a.items.fns[f].qname(),
            acq.class
        )
    };
    // class digraph edges with one representative witness each
    let mut edges: BTreeMap<(String, String), (usize, usize, Vec<String>)> = BTreeMap::new();
    for (fi, acqs) in local.iter().enumerate() {
        for acq in acqs {
            // later sibling acquisitions in the same lexical scope
            for b in acqs {
                if b.tok > acq.tok && b.tok < acq.scope_end {
                    edges
                        .entry((acq.class.clone(), b.class.clone()))
                        .or_insert_with(|| {
                            (
                                a.items.fns[fi].file,
                                acq.line,
                                vec![acq_frame(fi, acq), acq_frame(fi, b)],
                            )
                        });
                }
            }
            // classes acquired anywhere under a call made while held
            for site in &a.cg.calls[fi] {
                if site.tok <= acq.tok || site.tok >= acq.scope_end {
                    continue;
                }
                for class in &all[site.callee] {
                    edges.entry((acq.class.clone(), class.clone())).or_insert_with(|| {
                        let mut frames = vec![acq_frame(fi, acq)];
                        frames.push(a.call_frame(fi, site.line, site.callee));
                        let hops = &hops_for[class];
                        let mut cur = site.callee;
                        for step in a.cg.chain(hops, cur) {
                            frames.push(a.call_frame(cur, step.line, step.callee));
                            cur = step.callee;
                        }
                        if let Some(dst) = local[cur].iter().find(|x| &x.class == class) {
                            frames.push(acq_frame(cur, dst));
                        }
                        (a.items.fns[fi].file, acq.line, frames)
                    });
                }
            }
        }
    }
    // cycle detection over the class digraph; report each cycle once,
    // anchored at its lexicographically smallest class
    let mut adj: BTreeMap<&String, Vec<&String>> = BTreeMap::new();
    for (fr, _) in &edges {
        adj.entry(&fr.0).or_default().push(&fr.1);
    }
    let mut nodes: Vec<&String> = classes.iter().collect();
    nodes.sort();
    for &start in &nodes {
        // BFS from start back to start
        let mut parent: HashMap<&String, &String> = HashMap::new();
        let mut q: Vec<&String> = vec![start];
        let mut found: Option<Vec<&String>> = None;
        let mut seen: HashSet<&String> = HashSet::new();
        'bfs: while let Some(v) = q.pop() {
            for &w in adj.get(v).into_iter().flatten() {
                if w == start {
                    // reconstruct start -> ... -> v -> start
                    let mut path = vec![v];
                    let mut cur = v;
                    while let Some(&p) = parent.get(cur) {
                        path.push(p);
                        cur = p;
                    }
                    path.reverse();
                    path.push(start);
                    found = Some(path);
                    break 'bfs;
                }
                if seen.insert(w) {
                    parent.insert(w, v);
                    q.push(w);
                }
            }
        }
        // `path` is the full cycle: [start, ..., start]
        let Some(path) = found else { continue };
        if path[1..path.len() - 1].iter().any(|c| *c < start) {
            continue; // reported from the smallest class on the cycle
        }
        let desc: Vec<&str> = path.iter().map(|c| c.as_str()).collect();
        let mut witness: Vec<String> = Vec::new();
        let mut anchor: Option<(usize, usize)> = None;
        let mut prev = path[0];
        for &next in &path[1..] {
            if let Some((file, line, frames)) = edges.get(&(prev.clone(), next.clone())) {
                if anchor.is_none() {
                    anchor = Some((*file, *line));
                }
                witness.extend(frames.iter().cloned());
            }
            prev = next;
        }
        let (file, line) = anchor.unwrap_or((0, 1));
        let sf = &a.files[file];
        if sf.allowed("lock-order", line) {
            continue;
        }
        let mut v = viol(
            sf,
            line,
            "lock-order",
            format!(
                "potential lock-order cycle: {} — these classes are acquired in \
                 opposite orders on different call paths; a parallel schedule can \
                 deadlock (static complement of the HASS_CHECK runtime detector)",
                desc.join(" -> ")
            ),
        );
        v.witness = witness;
        out.push(v);
    }
}

// ---------------------------------------------------------------------
// R8 `thread-escape` — non-Send values flowing into escape sites
// ---------------------------------------------------------------------
// Escape sites are `spawn(...)` spans, `.send(...)` argument spans, and
// `Arc::new(...)` argument spans.  A violation fires when a span names:
// a binding whose type reaches `Rc`/`Cell`/`RefCell`/`UnsafeCell`
// (params and simple `let` bindings, via explicit type, non-Send
// constructor, or a call to a fn whose return type is tainted), a
// non-Send core type directly, a tainted type constructor, or a call to
// a fn returning a tainted type.  The witness chain explains the flow:
// binding site, then the type-graph path to the non-Send core.  This is
// value-level and per-fn: an `Rc` used wholly inside the spawned call
// tree (per-thread state like the engine `Runtime`) does not fire.

struct TaintedBinding {
    line: usize,
    frames: Vec<String>,
}

/// Candidate fns for the call whose name token sits at `t[m]`, stricter
/// than the call graph's resolution because R8 uses it for *taint*, where
/// a name collision poisons unrelated code: `Qual::name(` resolves to the
/// `impl Qual` method when one exists, else to free fns only (a
/// module-qualified path like `sessions::fused_decode`).  `Vec::new()` /
/// `HashMap::new()` therefore never pick up an in-crate `new` that
/// happens to return a tainted type.  Method calls (`.name(`) contribute
/// no taint at all: with no receiver types, `rx.clone()` would otherwise
/// resolve to whatever in-crate `clone` exists (e.g. `KvCache::clone`,
/// tainted) and poison every cloned channel handle in the crate.
fn call_candidates(a: &Analysis, t: &[Tok], m: usize) -> Vec<usize> {
    if tx(t, m.wrapping_sub(1)) == "." {
        return Vec::new();
    }
    let Some(cands) = a.items.by_name.get(&t[m].text) else { return Vec::new() };
    if tx(t, m.wrapping_sub(1)) == ":"
        && tx(t, m.wrapping_sub(2)) == ":"
        && t.get(m.wrapping_sub(3)).map(|k| k.kind == Kind::Ident).unwrap_or(false)
    {
        let q = tx(t, m.wrapping_sub(3));
        let on_q: Vec<usize> = cands
            .iter()
            .copied()
            .filter(|&c| a.items.fns[c].impl_target.as_deref() == Some(q))
            .collect();
        if !on_q.is_empty() {
            return on_q;
        }
        return cands
            .iter()
            .copied()
            .filter(|&c| a.items.fns[c].impl_target.is_none())
            .collect();
    }
    cands.clone()
}

/// Return-type taint per fn: (type shown in the message, chain frames).
fn ret_taint(a: &Analysis, taint: &HashMap<String, Vec<String>>) -> Vec<Option<(String, Vec<String>)>> {
    a.items
        .fns
        .iter()
        .map(|f| {
            for ty in &f.ret {
                if let Some(core) = non_send_core(a.items.canon(f.file, ty)) {
                    return Some((core.to_string(), Vec::new()));
                }
                if let Some(chain) = taint.get(ty) {
                    return Some((ty.clone(), chain.clone()));
                }
            }
            None
        })
        .collect()
}

fn r8_thread_escape(a: &Analysis, out: &mut Vec<Violation>) {
    let taint = type_taint(a);
    let rets = ret_taint(a, &taint);
    for (fi, f) in a.items.fns.iter().enumerate() {
        let Some((open, close)) = f.body else { continue };
        let t = &a.files[f.file].toks;
        let sf = &a.files[f.file];
        let path = a.path(f.file);
        // --- tainted bindings in this fn ---
        let mut bindings: HashMap<String, TaintedBinding> = HashMap::new();
        for (name, tys) in &f.params {
            for ty in tys {
                if let Some(core) = non_send_core(a.items.canon(f.file, ty)) {
                    bindings.insert(
                        name.clone(),
                        TaintedBinding {
                            line: f.line,
                            frames: vec![format!(
                                "{}:{}: param `{}` of {} has non-Send type `{}`",
                                path, f.line, name, f.qname(), core
                            )],
                        },
                    );
                    break;
                }
                if let Some(chain) = taint.get(ty) {
                    let mut frames = vec![format!(
                        "{}:{}: param `{}` of {} has type `{}`",
                        path, f.line, name, f.qname(), ty
                    )];
                    frames.extend(chain.iter().cloned());
                    bindings.insert(name.clone(), TaintedBinding { line: f.line, frames });
                    break;
                }
            }
        }
        let mut i = open + 1;
        while i < close {
            if t[i].kind != Kind::Ident || t[i].text != "let" {
                i += 1;
                continue;
            }
            let mut j = i + 1;
            if tx(t, j) == "mut" {
                j += 1;
            }
            // simple `let name` only; tuple/struct patterns are untracked
            if t.get(j).map(|k| k.kind != Kind::Ident).unwrap_or(true) {
                i = j + 1;
                continue;
            }
            let name = t[j].text.clone();
            let line = t[j].line;
            let mut k = j + 1;
            let mut tainted: Option<Vec<String>> = None;
            // explicit type annotation: `let name: T ... =`
            if tx(t, k) == ":" && tx(t, k + 1) != ":" {
                let ty_start = k + 1;
                let mut d = 0i64;
                while k < close {
                    match tx(t, k) {
                        "(" | "[" => d += 1,
                        ")" | "]" => d -= 1,
                        "=" | ";" if d <= 0 => break,
                        _ => {}
                    }
                    k += 1;
                }
                for m in ty_start..k {
                    if t[m].kind != Kind::Ident {
                        continue;
                    }
                    if let Some(core) = non_send_core(a.items.canon(f.file, &t[m].text)) {
                        tainted = Some(vec![format!(
                            "{}:{}: `{}` declared with non-Send type `{}`",
                            path, line, name, core
                        )]);
                        break;
                    }
                    if let Some(chain) = taint.get(&t[m].text) {
                        let mut fr = vec![format!(
                            "{}:{}: `{}` declared as `{}`",
                            path, line, name, t[m].text
                        )];
                        fr.extend(chain.iter().cloned());
                        tainted = Some(fr);
                        break;
                    }
                }
            }
            // RHS: `= ... ;` at depth 0
            if tx(t, k) == "=" {
                let mut d = 0i64;
                let mut m = k + 1;
                while m < close {
                    match tx(t, m) {
                        "(" | "[" | "{" => d += 1,
                        ")" | "]" | "}" => d -= 1,
                        ";" if d <= 0 => break,
                        _ => {}
                    }
                    if tainted.is_none() && t[m].kind == Kind::Ident {
                        if let Some(core) = non_send_core(a.items.canon(f.file, &t[m].text)) {
                            tainted = Some(vec![format!(
                                "{}:{}: `{}` bound from `{}` — non-Send",
                                path, line, name, core
                            )]);
                        } else if tx(t, m + 1) == "(" {
                            if let Some((ty, chain)) = call_candidates(a, t, m)
                                .iter()
                                .find_map(|c| rets[*c].as_ref())
                            {
                                let mut fr = vec![format!(
                                    "{}:{}: `{}` bound from {}() returning `{}`",
                                    path, line, name, t[m].text, ty
                                )];
                                fr.extend(chain.iter().cloned());
                                tainted = Some(fr);
                            }
                        }
                    }
                    m += 1;
                }
                k = m;
            }
            if let Some(frames) = tainted {
                bindings.insert(name, TaintedBinding { line, frames });
            }
            i = k + 1;
        }
        // --- escape spans in this fn ---
        let mut reported: HashSet<(usize, String)> = HashSet::new();
        let mut i = open + 1;
        while i < close {
            let kind = if t[i].kind == Kind::Ident && t[i].text == "spawn" && tx(t, i + 1) == "(" {
                Some(("spawn", i + 1))
            } else if t[i].kind == Kind::Ident
                && t[i].text == "send"
                && tx(t, i.wrapping_sub(1)) == "."
                && tx(t, i + 1) == "("
            {
                Some(("channel send", i + 1))
            } else if t[i].kind == Kind::Ident
                && t[i].text == "Arc"
                && tx(t, i + 1) == ":"
                && tx(t, i + 2) == ":"
                && tx(t, i + 3) == "new"
                && tx(t, i + 4) == "("
            {
                Some(("Arc::new", i + 4))
            } else {
                None
            };
            let Some((kind, popen)) = kind else {
                i += 1;
                continue;
            };
            let mut d = 0i64;
            let mut j = popen;
            while j < t.len() {
                match tx(t, j) {
                    "(" => {
                        d += 1;
                        j += 1;
                        continue;
                    }
                    ")" => {
                        d -= 1;
                        if d == 0 {
                            break;
                        }
                        j += 1;
                        continue;
                    }
                    _ => {}
                }
                if t[j].kind == Kind::Ident {
                    let name = &t[j].text;
                    let line = t[j].line;
                    let mut fire: Option<(String, Vec<String>)> = None;
                    if let Some(b) = bindings.get(name) {
                        let mut fr = vec![format!(
                            "{}:{}: `{}` (bound at line {}) is captured by the {} here",
                            path, line, name, b.line, kind
                        )];
                        fr.extend(b.frames.iter().cloned());
                        fire = Some((
                            format!(
                                "`{name}` carries non-Send state into a {kind} — \
                                 Rc/Cell state must not cross threads (Arc page-pool \
                                 migration gate)"
                            ),
                            fr,
                        ));
                    } else if let Some(core) = non_send_core(a.items.canon(f.file, name)) {
                        fire = Some((
                            format!("non-Send `{core}` named directly inside a {kind} span"),
                            Vec::new(),
                        ));
                    } else if taint.contains_key(name)
                        && matches!(tx(t, j + 1), "{" | "(" | ":")
                    {
                        let mut fr = vec![format!(
                            "{}:{}: `{}` constructed inside the {} span",
                            path, line, name, kind
                        )];
                        fr.extend(taint[name].iter().cloned());
                        fire = Some((
                            format!(
                                "`{name}` (which transitively holds non-Send state) is \
                                 built inside a {kind} span"
                            ),
                            fr,
                        ));
                    } else if tx(t, j + 1) == "(" {
                        if let Some((ty, chain)) = call_candidates(a, t, j)
                            .iter()
                            .find_map(|c| rets[*c].as_ref())
                        {
                            let mut fr = vec![format!(
                                "{}:{}: result of {}() (returns `{}`) flows into the {}",
                                path, line, name, ty, kind
                            )];
                            fr.extend(chain.iter().cloned());
                            fire = Some((
                                format!(
                                    "call result of `{name}()` carries non-Send \
                                     state into a {kind}"
                                ),
                                fr,
                            ));
                        }
                    }
                    if let Some((msg, frames)) = fire {
                        if !sf.allowed("thread-escape", line)
                            && reported.insert((line, name.clone()))
                        {
                            let mut v = viol(sf, line, "thread-escape", msg);
                            v.witness = frames;
                            out.push(v);
                        }
                    }
                }
                j += 1;
            }
            i = j + 1;
        }
    }
}

// ---------------------------------------------------------------------
// R9 `stamp-discipline` — interprocedural marker discipline
// ---------------------------------------------------------------------
// In `kvcache/mod.rs`: the storage-write primitives are `page_mut`,
// `next_stamp`, and `dedup_page*`.  Any fn that can REACH a primitive
// through any call chain must either carry the `#[hass::mutates_storage]`
// doc marker or be a private helper on some marked fn's call path;
// conversely a marked fn whose call tree never reaches a stamp bump is
// a stale marker.  This replaces the old single-body scan: a pub fn that
// merely *allocates* pages three calls down (fresh `(id,stamp)`
// identities) is a storage mutation the Arc migration must see.

fn r9_stamp_discipline(a: &Analysis, out: &mut Vec<Violation>) {
    let kv_files: HashSet<usize> = (0..a.files.len())
        .filter(|&i| a.files[i].path.ends_with("kvcache/mod.rs"))
        .collect();
    if kv_files.is_empty() {
        return;
    }
    let is_prim =
        |n: &str| n == "page_mut" || n == "next_stamp" || n.starts_with("dedup_page");
    let prims: HashSet<usize> = a
        .items
        .fns
        .iter()
        .enumerate()
        .filter(|(_, f)| kv_files.contains(&f.file) && is_prim(&f.name))
        .map(|(i, _)| i)
        .collect();
    let hops = a.cg.next_hops(&prims);
    let marked: HashSet<usize> = a
        .items
        .fns
        .iter()
        .enumerate()
        .filter(|(_, f)| f.marked)
        .map(|(i, _)| i)
        .collect();
    let marked_reach = a.cg.reachable_from(&marked);
    for &(file, line) in &a.items.dangling_markers {
        if kv_files.contains(&file) {
            out.push(viol(
                &a.files[file],
                line,
                "stamp-discipline",
                "`#[hass::mutates_storage]` marker with no fn in the next 12 lines"
                    .to_string(),
            ));
        }
    }
    for (fi, f) in a.items.fns.iter().enumerate() {
        if !kv_files.contains(&f.file) || prims.contains(&fi) {
            continue;
        }
        let sf = &a.files[f.file];
        // direct stamp/page writes in the body (covers `stamp.set` and
        // primitive names the call graph could not resolve)
        let mut local_write = false;
        if let Some((open, close)) = f.body {
            let t = &a.files[f.file].toks;
            for k in (open + 1)..close {
                if t[k].kind != Kind::Ident {
                    continue;
                }
                if is_prim(&t[k].text)
                    || (t[k].text == "stamp" && tx(t, k + 1) == "." && tx(t, k + 2) == "set")
                {
                    local_write = true;
                    break;
                }
            }
        }
        let reaches = local_write || hops.contains_key(&fi);
        if f.marked && f.body.is_some() && !reaches {
            if !sf.allowed("stamp-discipline", f.line) {
                out.push(viol(
                    sf,
                    f.line,
                    "stamp-discipline",
                    format!(
                        "`{}` is marked #[hass::mutates_storage] but no call chain from \
                         it reaches a stamp bump (page_mut / dedup_page / next_stamp / \
                         stamp.set) — a write without a bump lets (id,stamp) alias two \
                         different page contents",
                        f.name
                    ),
                ));
            }
            continue;
        }
        if !f.marked && reaches {
            // witness: the call chain down to the primitive
            let mut witness: Vec<String> = Vec::new();
            let mut cur = fi;
            for step in a.cg.chain(&hops, fi) {
                witness.push(a.call_frame(cur, step.line, step.callee));
                cur = step.callee;
            }
            if witness.is_empty() && local_write {
                witness.push(format!(
                    "{}:{}: {} writes page storage directly",
                    a.path(f.file),
                    f.line,
                    f.qname()
                ));
            }
            if f.is_pub {
                if !sf.allowed("stamp-discipline", f.line) {
                    let mut v = viol(
                        sf,
                        f.line,
                        "stamp-discipline",
                        format!(
                            "pub fn `{}` reaches page-storage writes through its call \
                             chain but lacks the #[hass::mutates_storage] doc marker",
                            f.name
                        ),
                    );
                    v.witness = witness;
                    out.push(v);
                }
            } else if !marked_reach.contains(&fi) && !sf.allowed("stamp-discipline", f.line) {
                let mut v = viol(
                    sf,
                    f.line,
                    "stamp-discipline",
                    format!(
                        "private fn `{}` reaches page-storage writes but is not on any \
                         marked fn's call path — either mark it or route it under a \
                         marked entry point",
                        f.name
                    ),
                );
                v.witness = witness;
                out.push(v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::run_sources;

    fn rules_fired(sources: &[(&str, &str)]) -> Vec<String> {
        run_sources(sources).into_iter().map(|v| v.rule).collect()
    }

    // ---- R1 ----

    #[test]
    fn r1_fires_on_unwrap_in_fused_path() {
        let fired = rules_fired(&[(
            "rust/src/scheduler/mod.rs",
            "fn f(x: Option<u32>) -> u32 { x.unwrap() }",
        )]);
        assert_eq!(fired, vec!["no-unwrap"]);
    }

    #[test]
    fn r1_fires_on_expect_and_call_indexing() {
        let v = run_sources(&[(
            "rust/src/kvcache/mod.rs",
            "fn f(x: Option<u32>) -> u32 { x.expect(\"boom\") }\n\
             fn g() -> u32 { h()[0] }\nfn h() -> Vec<u32> { vec![] }",
        )]);
        assert_eq!(v.len(), 2);
        assert!(v.iter().all(|v| v.rule == "no-unwrap"));
    }

    #[test]
    fn r1_annotated_does_not_fire() {
        let fired = rules_fired(&[(
            "rust/src/scheduler/mod.rs",
            "fn f(x: Option<u32>) -> u32 {\n\
             // hass-lint: allow(no-unwrap) — x was checked by the caller\n\
             x.unwrap()\n}",
        )]);
        assert!(fired.is_empty(), "{fired:?}");
    }

    #[test]
    fn r1_ignores_non_fused_paths_and_tests() {
        let fired = rules_fired(&[
            ("rust/src/tables/mod.rs", "fn f(x: Option<u32>) -> u32 { x.unwrap() }"),
            (
                "rust/src/scheduler/mod.rs",
                "#[cfg(test)]\nmod tests { fn f(x: Option<u32>) -> u32 { x.unwrap() } }",
            ),
            ("rust/src/kvcache/props.rs", "fn f(x: Option<u32>) -> u32 { x.unwrap() }"),
        ]);
        assert!(fired.is_empty(), "{fired:?}");
    }

    #[test]
    fn r1_leaves_unwrap_or_else_alone() {
        let fired = rules_fired(&[(
            "rust/src/scheduler/mod.rs",
            "fn f(m: &std::sync::Mutex<u32>) -> u32 { \
             *m.lock().unwrap_or_else(|p| p.into_inner()) }",
        )]);
        assert!(fired.is_empty(), "{fired:?}");
    }

    // ---- R2 ----

    #[test]
    fn r2_fires_on_rc_field_behind_arc() {
        let v = run_sources(&[(
            "rust/src/anywhere.rs",
            "use std::rc::Rc; use std::sync::Arc;\n\
             struct Inner { p: Rc<u32> }\n\
             struct Outer { inner: Inner }\n\
             fn f(x: Arc<Outer>) { let _ = x; }",
        )]);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "send-hygiene");
        // witness: the field chain from the Arc root down to the Rc
        assert!(v[0].witness.iter().any(|w| w.contains("Outer embeds Inner")), "{:?}", v[0].witness);
    }

    #[test]
    fn r2_alias_of_rc_is_caught() {
        let fired = rules_fired(&[(
            "rust/src/anywhere.rs",
            "use std::rc::Rc as Shared;\n\
             struct Inner { p: Shared<u32> }\n\
             fn f(x: std::sync::Arc<Inner>) { let _ = x; }",
        )]);
        assert_eq!(fired, vec!["send-hygiene"]);
    }

    #[test]
    fn r2_fires_on_cell_in_channel_payload() {
        let fired = rules_fired(&[(
            "rust/src/anywhere.rs",
            "enum Msg { Go(State) }\n\
             struct State { c: std::cell::Cell<u64> }\n\
             fn f(tx: std::sync::mpsc::Sender<Msg>) { let _ = tx; }",
        )]);
        assert_eq!(fired, vec!["send-hygiene"]);
    }

    #[test]
    fn r2_unreachable_rc_is_fine() {
        // Cell in a type never sent across a thread boundary: allowed —
        // this is the kvcache Page today.
        let fired = rules_fired(&[(
            "rust/src/anywhere.rs",
            "struct Page { s: std::cell::Cell<u64> }\n\
             struct Sent { n: u64 }\n\
             fn f(x: std::sync::Arc<Sent>) { let _ = x; }",
        )]);
        assert!(fired.is_empty(), "{fired:?}");
    }

    #[test]
    fn r2_annotated_does_not_fire() {
        let fired = rules_fired(&[(
            "rust/src/anywhere.rs",
            "struct Inner { p: std::rc::Rc<u32> } // hass-lint: allow(send-hygiene) — audited single-thread\n\
             fn f(x: std::sync::Arc<Inner>) { let _ = x; }",
        )]);
        assert!(fired.is_empty(), "{fired:?}");
    }

    // ---- R7 ----

    #[test]
    fn r7_reports_cross_fn_inversion_once_with_witness() {
        let v = run_sources(&[(
            "rust/src/scheduler/mod.rs",
            "fn a() { let _t = trace(WORKER_QUEUE); helper(); }\n\
             fn helper() { let _s = trace(STATS); }\n\
             fn b() { let _t = trace(STATS); other(); }\n\
             fn other() { let _q = trace(WORKER_QUEUE); }",
        )]);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "lock-order");
        assert!(v[0].msg.contains("STATS -> WORKER_QUEUE -> STATS"), "{}", v[0].msg);
        // both edges carry full witness chains: acquire, call, acquire
        let w = &v[0].witness;
        assert_eq!(w.len(), 6, "{w:?}");
        assert!(w[0].contains("b acquires STATS"), "{w:?}");
        assert!(w[1].contains("b -> other"), "{w:?}");
        assert!(w[2].contains("other acquires WORKER_QUEUE"), "{w:?}");
        assert!(w[3].contains("a acquires WORKER_QUEUE"), "{w:?}");
        assert!(w[5].contains("helper acquires STATS"), "{w:?}");
    }

    #[test]
    fn r7_consistent_order_is_clean() {
        let fired = rules_fired(&[(
            "rust/src/scheduler/mod.rs",
            "fn a() { let _t = trace(WORKER_QUEUE); helper(); }\n\
             fn helper() { let _s = trace(STATS); }",
        )]);
        assert!(fired.is_empty(), "{fired:?}");
    }

    #[test]
    fn r7_same_class_nesting_is_a_self_loop() {
        let v = run_sources(&[(
            "rust/src/scheduler/mod.rs",
            "fn f() { let _a = trace(STATS); let _b = trace(STATS); }",
        )]);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "lock-order");
        assert!(v[0].msg.contains("STATS -> STATS"), "{}", v[0].msg);
    }

    #[test]
    fn r7_disjoint_scopes_do_not_nest() {
        // the second token is acquired after the first's block closed
        let fired = rules_fired(&[(
            "rust/src/scheduler/mod.rs",
            "fn f() { { let _a = trace(STATS); } { let _b = trace(WORKER_QUEUE); } }\n\
             fn g() { { let _a = trace(WORKER_QUEUE); } { let _b = trace(STATS); } }",
        )]);
        assert!(fired.is_empty(), "{fired:?}");
    }

    // ---- R8 ----

    #[test]
    fn r8_fires_on_rc_in_spawn_closure() {
        let v = run_sources(&[(
            "rust/src/anywhere.rs",
            "fn f() { let r = std::rc::Rc::new(1u32); \
             std::thread::spawn(move || { let _ = Rc::strong_count(&r); }); }",
        )]);
        assert!(!v.is_empty(), "{v:?}");
        assert!(v.iter().all(|x| x.rule == "thread-escape"), "{v:?}");
    }

    #[test]
    fn r8_handle_returned_by_helper_into_spawn() {
        let v = run_sources(&[(
            "rust/src/anywhere.rs",
            "use std::rc::Rc;\n\
             struct Handle { r: Rc<u32> }\n\
             fn make_handle() -> Handle { Handle { r: Rc::new(7) } }\n\
             fn f() { let h = make_handle(); std::thread::spawn(move || { let _ = h; }); }",
        )]);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "thread-escape");
        let w = &v[0].witness;
        assert!(w[0].contains("captured by the spawn"), "{w:?}");
        assert!(w[1].contains("make_handle() returning `Handle`"), "{w:?}");
        assert!(w.last().map(|s| s.contains("holds non-Send `Rc`")).unwrap_or(false), "{w:?}");
    }

    #[test]
    fn r8_tainted_binding_into_send() {
        let v = run_sources(&[(
            "rust/src/anywhere.rs",
            "use std::cell::Cell;\n\
             struct Payload { c: Cell<u64> }\n\
             fn g(q: &Queue) { let p = Payload { c: Cell::new(0) }; q.send(p); }",
        )]);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "thread-escape");
        assert!(v[0].msg.contains("channel send"), "{}", v[0].msg);
    }

    #[test]
    fn r8_rc_local_to_one_thread_is_fine() {
        let fired = rules_fired(&[(
            "rust/src/anywhere.rs",
            "fn f() { let r = std::rc::Rc::new(1u32); let _ = r.clone(); }",
        )]);
        assert!(fired.is_empty(), "{fired:?}");
    }

    #[test]
    fn r8_annotated_does_not_fire() {
        let fired = rules_fired(&[(
            "rust/src/anywhere.rs",
            "fn f() { let r = std::rc::Rc::new(1u32);\n\
             // hass-lint: allow(thread-escape) — spawn target joins before f returns\n\
             spawn(move || { let _ = r; }); }",
        )]);
        assert!(fired.is_empty(), "{fired:?}");
    }

    // ---- R9 ----

    #[test]
    fn r9_fires_on_marked_fn_without_bump() {
        let fired = rules_fired(&[(
            "rust/src/kvcache/mod.rs",
            "struct KvCache { n: usize }\n\
             impl KvCache {\n\
             /// #[hass::mutates_storage]\n\
             pub fn touch(&mut self) { self.n += 1; }\n\
             }",
        )]);
        assert_eq!(fired, vec!["stamp-discipline"]);
    }

    #[test]
    fn r9_fires_on_unmarked_writer() {
        let fired = rules_fired(&[(
            "rust/src/kvcache/mod.rs",
            "struct KvCache { n: usize }\n\
             impl KvCache {\n\
             fn page_mut(&mut self) -> &mut usize { &mut self.n }\n\
             pub fn write(&mut self) { *self.page_mut() = 3; }\n\
             }",
        )]);
        assert_eq!(fired, vec!["stamp-discipline"]);
    }

    #[test]
    fn r9_transitive_reach_fires_with_chain() {
        let v = run_sources(&[(
            "rust/src/kvcache/mod.rs",
            "struct KvCache { n: usize }\n\
             impl KvCache {\n\
             fn page_mut(&mut self) -> &mut usize { &mut self.n }\n\
             fn ensure(&mut self) { self.page_mut(); }\n\
             pub fn outer(&mut self) { self.ensure(); }\n\
             }",
        )]);
        // `ensure` (private, not under any marked fn) and `outer` (pub,
        // unmarked, reaches page_mut two calls down) both fire
        assert_eq!(v.len(), 2, "{v:?}");
        assert!(v.iter().all(|x| x.rule == "stamp-discipline"), "{v:?}");
        let outer = v.iter().find(|x| x.msg.contains("`outer`")).expect("outer finding");
        assert!(outer.witness.iter().any(|w| w.contains("KvCache::outer -> KvCache::ensure")), "{:?}", outer.witness);
        assert!(outer.witness.iter().any(|w| w.contains("KvCache::ensure -> KvCache::page_mut")), "{:?}", outer.witness);
    }

    #[test]
    fn r9_marked_entry_point_covers_private_helpers() {
        let fired = rules_fired(&[(
            "rust/src/kvcache/mod.rs",
            "struct KvCache { n: usize }\n\
             impl KvCache {\n\
             fn page_mut(&mut self) -> &mut usize { &mut self.n }\n\
             fn ensure(&mut self) { self.page_mut(); }\n\
             /// #[hass::mutates_storage]\n\
             pub fn outer(&mut self) { self.ensure(); }\n\
             }",
        )]);
        assert!(fired.is_empty(), "{fired:?}");
    }

    #[test]
    fn r9_dangling_marker_fires() {
        let fired = rules_fired(&[(
            "rust/src/kvcache/mod.rs",
            "/// #[hass::mutates_storage]\nstruct NotAFn;\n",
        )]);
        assert_eq!(fired, vec!["stamp-discipline"]);
    }

    #[test]
    fn r9_only_applies_to_kvcache() {
        let fired = rules_fired(&[(
            "rust/src/engine/sessions.rs",
            "struct KvCache { n: usize }\n\
             impl KvCache { fn page_mut(&mut self) {} pub fn w(&mut self) { self.page_mut(); } }",
        )]);
        assert!(fired.is_empty(), "{fired:?}");
    }

    // ---- R4 ----

    #[test]
    fn r4_reports_drift_and_dead_keys() {
        let v = run_sources(&[(
            "rust/src/server/mod.rs",
            "fn parse(j: &Json) { let _ = j.str_at(\"promt\"); }\n\
             fn emit() -> Json { Json::obj(vec![(\"prompt\", Json::Bool(true))]) }",
        )]);
        // "promt" is read but never emitted (drift); "prompt" is emitted
        // but never read (dead — the typo severed both directions)
        let fired: Vec<&str> = v.iter().map(|x| x.rule.as_str()).collect();
        assert_eq!(fired, vec!["wire-drift", "wire-dead"], "{v:?}");
        assert_eq!(v[1].severity, "warning");
    }

    #[test]
    fn r4_embedded_raw_string_counts_as_emit() {
        let fired = rules_fired(&[(
            "rust/src/server/mod.rs",
            "fn stats(c: &mut Client) { c.send(r#\"{\"stats\":true}\"#); }\n\
             fn parse(j: &Json) { let _ = j.get(\"stats\"); }",
        )]);
        assert!(fired.is_empty(), "{fired:?}");
    }

    #[test]
    fn r4_format_escaped_key_counts_as_emit() {
        let fired = rules_fired(&[(
            "rust/src/server/mod.rs",
            "fn cancel(id: u64) -> String { format!(\"{{\\\"cancel\\\":{id}}}\") }\n\
             fn parse(j: &Json) { let _ = j.get(\"cancel\"); }",
        )]);
        assert!(fired.is_empty(), "{fired:?}");
    }

    #[test]
    fn r4_ignores_non_wire_files() {
        let fired = rules_fired(&[(
            "rust/src/util/json.rs",
            "fn f(j: &Json) { let _ = j.get(\"whatever\"); }",
        )]);
        assert!(fired.is_empty(), "{fired:?}");
    }

    #[test]
    fn r4_helper_forwarded_reads_are_tracked() {
        // `req_field` forwards its &str param into u64_at, so the string
        // literal at its call site is a read of that key
        let v = run_sources(&[(
            "rust/src/server/mod.rs",
            "fn req_field(j: &Json, name: &str) -> u64 { j.u64_at(name) }\n\
             fn parse(j: &Json) { let _ = req_field(j, \"missing_key\"); }",
        )]);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "wire-drift");
        assert!(v[0].msg.contains("missing_key"), "{}", v[0].msg);
        assert!(v[0].msg.contains("key-reader helper"), "{}", v[0].msg);
    }

    #[test]
    fn r4_helper_read_of_emitted_key_is_clean() {
        let fired = rules_fired(&[(
            "rust/src/server/mod.rs",
            "fn req_field(j: &Json, name: &str) -> u64 { j.u64_at(name) }\n\
             fn parse(j: &Json) { let _ = req_field(j, \"jobs\"); }\n\
             fn emit() -> Json { Json::obj(vec![(\"jobs\", Json::U64(1))]) }",
        )]);
        assert!(fired.is_empty(), "{fired:?}");
    }

    #[test]
    fn r4_dead_key_rescued_by_test_reader() {
        // wire-dead scans the unstripped token stream: a #[cfg(test)]
        // consumer anywhere in the crate counts
        let fired = rules_fired(&[
            (
                "rust/src/server/mod.rs",
                "fn emit() -> Json { Json::obj(vec![(\"ghost\", Json::Bool(true))]) }",
            ),
            (
                "rust/src/client.rs",
                "#[cfg(test)]\nmod t { fn f(j: &Json) { let _ = j.get(\"ghost\"); } }",
            ),
        ]);
        assert!(fired.is_empty(), "{fired:?}");
    }

    // ---- R5 ----

    #[test]
    fn r5_fires_on_bare_spawn() {
        let fired = rules_fired(&[(
            "rust/src/server/mod.rs",
            "fn f() { std::thread::spawn(move || { loop {} }); }",
        )]);
        assert_eq!(fired, vec!["panic-isolation"]);
    }

    #[test]
    fn r5_catch_unwind_in_span_is_clean() {
        let fired = rules_fired(&[(
            "rust/src/scheduler/mod.rs",
            "fn f() { std::thread::spawn(move || { \
             let _ = std::panic::catch_unwind(|| work()); }); }\nfn work() {}",
        )]);
        assert!(fired.is_empty(), "{fired:?}");
    }

    #[test]
    fn r5_annotated_does_not_fire() {
        let fired = rules_fired(&[(
            "rust/src/server/mod.rs",
            "fn f() {\n\
             // hass-lint: allow(panic-isolation) — joined immediately below\n\
             std::thread::spawn(move || { loop {} });\n}",
        )]);
        assert!(fired.is_empty(), "{fired:?}");
    }

    // ---- R-unsafe ----

    #[test]
    fn unsafe_without_safety_comment_fires() {
        let fired = rules_fired(&[(
            "rust/src/runtime/tensor.rs",
            "fn f(p: *const u8) -> u8 { unsafe { *p } }",
        )]);
        assert_eq!(fired, vec!["unsafe-comment"]);
    }

    #[test]
    fn unsafe_with_safety_comment_is_clean() {
        let fired = rules_fired(&[(
            "rust/src/runtime/tensor.rs",
            "fn f(p: *const u8) -> u8 {\n\
             // SAFETY: caller guarantees p is valid for reads\n\
             unsafe { *p }\n}",
        )]);
        assert!(fired.is_empty(), "{fired:?}");
    }
}
