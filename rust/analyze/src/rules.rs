//! The rule engine.  Every rule is a lexical approximation (see module
//! docs in `lexer.rs`); each one documents the exact token pattern it
//! matches so a surprising report can be traced.

use std::collections::{HashMap, HashSet};

use crate::lexer::{Kind, Tok};
use crate::{SourceFile, Violation};

/// Fused-path modules: the code where a panic kills a worker cycle and a
/// stale page aliases another session's KV.  `kvcache/props.rs` is a
/// test-only oracle suite (its own file, so `#[cfg(test)]` stripping
/// can't see the `mod` wrapper in `kvcache/mod.rs`) and is exempt.
fn is_fused_path(p: &str) -> bool {
    (p.contains("scheduler/") || p.ends_with("engine/sessions.rs") || p.contains("kvcache/"))
        && !p.ends_with("kvcache/props.rs")
}

/// Files that parse or emit wire-protocol JSON keys.
fn is_wire_file(p: &str) -> bool {
    p.ends_with("server/mod.rs") || p.ends_with("main.rs")
}

/// Files that spawn worker / pump threads.
fn is_thread_file(p: &str) -> bool {
    p.ends_with("scheduler/mod.rs") || p.ends_with("server/mod.rs")
}

pub fn check_crate(files: &[SourceFile]) -> Vec<Violation> {
    let mut out: Vec<Violation> = Vec::new();
    for f in files {
        r1_no_unwrap(f, &mut out);
        r3_stamp_discipline(f, &mut out);
        r5_panic_isolation(f, &mut out);
        r_unsafe_comment(f, &mut out);
    }
    r2_send_hygiene(files, &mut out);
    r4_wire_drift(files, &mut out);
    out
}

fn viol(f: &SourceFile, line: usize, rule: &str, msg: String) -> Violation {
    Violation { file: f.path.clone(), line, rule: rule.to_string(), msg }
}

fn tx(t: &[Tok], i: usize) -> &str {
    t.get(i).map(|k| k.text.as_str()).unwrap_or("")
}

/// Matching `}` for every `{` (token indices).
fn brace_pairs(t: &[Tok]) -> HashMap<usize, usize> {
    let mut stack: Vec<usize> = Vec::new();
    let mut map: HashMap<usize, usize> = HashMap::new();
    for (i, tk) in t.iter().enumerate() {
        match tk.text.as_str() {
            "{" => stack.push(i),
            "}" => {
                if let Some(o) = stack.pop() {
                    map.insert(o, i);
                }
            }
            _ => {}
        }
    }
    map
}

// ---------------------------------------------------------------------
// R1 `no-unwrap`
// ---------------------------------------------------------------------
// Pattern: `.unwrap(` / `.expect(` (exact identifier, so `unwrap_or_else`
// and friends are untouched), plus `)[` — indexing straight into a call
// result, where no named binding carries a length proof.  Fused-path
// files only; other indexing (named slices, tensors) is handled by the
// shadow sanitizer at runtime, not lexically.

fn r1_no_unwrap(f: &SourceFile, out: &mut Vec<Violation>) {
    if !is_fused_path(&f.path) {
        return;
    }
    let t = &f.toks;
    for i in 0..t.len() {
        if t[i].kind == Kind::Ident
            && (t[i].text == "unwrap" || t[i].text == "expect")
            && tx(t, i.wrapping_sub(1)) == "."
            && tx(t, i + 1) == "("
            && !f.allowed("no-unwrap", t[i].line)
        {
            out.push(viol(
                f,
                t[i].line,
                "no-unwrap",
                format!(
                    ".{}() on the fused path — a panic here kills a worker cycle; \
                     return through the existing Result plumbing or annotate with \
                     `hass-lint: allow(no-unwrap)`",
                    t[i].text
                ),
            ));
        }
        if t[i].text == ")" && tx(t, i + 1) == "[" && !f.allowed("no-unwrap", t[i].line) {
            out.push(viol(
                f,
                t[i].line,
                "no-unwrap",
                "indexing straight into a call result on the fused path — bind it and \
                 bounds-check, or annotate with `hass-lint: allow(no-unwrap)`"
                    .to_string(),
            ));
        }
    }
}

// ---------------------------------------------------------------------
// R2 `send-hygiene`
// ---------------------------------------------------------------------
// Thread-crossing roots are type names inside `Arc<...>` / `Sender<...>`
// / `SyncSender<...>` / `Receiver<...>` generics, `channel::<T>` /
// `sync_channel::<T>` turbofish, and `Arc::new(...)` construction.  From
// those roots the rule walks struct/enum field types transitively and
// flags any `Rc` / `Cell` / `RefCell` / `UnsafeCell` field it reaches —
// exactly the state the Arc page-pool migration must not smuggle across
// a thread.  It also flags those identifiers named directly inside a
// `spawn(...)` argument span (closure captures).

const NON_SEND: [&str; 4] = ["Rc", "Cell", "RefCell", "UnsafeCell"];

struct TypeInfo {
    file: usize,
    /// Identifiers in field-type position, with the line they sit on.
    fields: Vec<(String, usize)>,
}

fn collect_types(files: &[SourceFile]) -> HashMap<String, TypeInfo> {
    let mut map: HashMap<String, TypeInfo> = HashMap::new();
    for (fi, f) in files.iter().enumerate() {
        let t = &f.toks;
        let pairs = brace_pairs(t);
        let mut i = 0usize;
        while i < t.len() {
            if t[i].kind != Kind::Ident || (t[i].text != "struct" && t[i].text != "enum") {
                i += 1;
                continue;
            }
            let Some(name) = t.get(i + 1) else { break };
            if name.kind != Kind::Ident {
                i += 1;
                continue;
            }
            // skip generics to the body start: `{`, `(`, or `;`
            let mut angle = 0i64;
            let mut j = i + 2;
            while j < t.len() {
                match tx(t, j) {
                    "<" => angle += 1,
                    ">" => angle -= 1,
                    "{" | "(" | ";" if angle <= 0 => break,
                    _ => {}
                }
                j += 1;
            }
            if j >= t.len() || tx(t, j) == ";" {
                i = j + 1;
                continue;
            }
            let (open, close) = if tx(t, j) == "{" {
                match pairs.get(&j) {
                    Some(&c) => (j, c),
                    None => {
                        i = j + 1;
                        continue;
                    }
                }
            } else {
                // tuple struct / unit-with-parens: match the `)`
                let mut d = 0i64;
                let mut k = j;
                let mut close = j;
                while k < t.len() {
                    match tx(t, k) {
                        "(" => d += 1,
                        ")" => {
                            d -= 1;
                            if d == 0 {
                                close = k;
                                break;
                            }
                        }
                        _ => {}
                    }
                    k += 1;
                }
                (j, close)
            };
            let mut fields: Vec<(String, usize)> = Vec::new();
            for k in (open + 1)..close {
                let tk = &t[k];
                if tk.kind != Kind::Ident {
                    continue;
                }
                if matches!(tk.text.as_str(), "pub" | "crate" | "super" | "in" | "dyn" | "mut") {
                    continue;
                }
                // `ident :` (single colon) is a field name, not a type
                let single_colon =
                    tx(t, k + 1) == ":" && tx(t, k + 2) != ":";
                if single_colon {
                    continue;
                }
                fields.push((tk.text.clone(), tk.line));
            }
            map.insert(name.text.clone(), TypeInfo { file: fi, fields });
            i = close + 1;
        }
    }
    map
}

/// Identifiers inside the generic argument list opening at `t[open]`
/// (which must be `<`).  Bounded walk; `->` return arrows don't close.
fn generic_idents(t: &[Tok], open: usize, roots: &mut HashSet<String>) {
    let mut d = 0i64;
    let mut j = open;
    let mut budget = 96usize;
    while j < t.len() && budget > 0 {
        budget -= 1;
        match tx(t, j) {
            "<" => d += 1,
            ">" => {
                if j == 0 || tx(t, j - 1) != "-" {
                    d -= 1;
                    if d == 0 {
                        break;
                    }
                }
            }
            _ => {
                if t[j].kind == Kind::Ident {
                    roots.insert(t[j].text.clone());
                }
            }
        }
        j += 1;
    }
}

fn collect_roots(files: &[SourceFile], types: &HashMap<String, TypeInfo>) -> HashSet<String> {
    let mut roots: HashSet<String> = HashSet::new();
    for f in files {
        let t = &f.toks;
        for i in 0..t.len() {
            if t[i].kind != Kind::Ident {
                continue;
            }
            let name = t[i].text.as_str();
            if matches!(name, "Arc" | "Sender" | "SyncSender" | "Receiver") && tx(t, i + 1) == "<"
            {
                generic_idents(t, i + 1, &mut roots);
            }
            if matches!(name, "channel" | "sync_channel") {
                // turbofish: channel::<T>(...)
                for j in (i + 1)..(i + 5).min(t.len()) {
                    if tx(t, j) == "<" {
                        generic_idents(t, j, &mut roots);
                        break;
                    }
                    if tx(t, j) != ":" {
                        break;
                    }
                }
            }
            if name == "Arc"
                && tx(t, i + 1) == ":"
                && tx(t, i + 2) == ":"
                && tx(t, i + 3) == "new"
                && tx(t, i + 4) == "("
            {
                let mut d = 0i64;
                let mut j = i + 4;
                while j < t.len() {
                    match tx(t, j) {
                        "(" => d += 1,
                        ")" => {
                            d -= 1;
                            if d == 0 {
                                break;
                            }
                        }
                        _ => {
                            if t[j].kind == Kind::Ident && types.contains_key(&t[j].text) {
                                roots.insert(t[j].text.clone());
                            }
                        }
                    }
                    j += 1;
                }
            }
        }
    }
    roots
}

fn r2_send_hygiene(files: &[SourceFile], out: &mut Vec<Violation>) {
    let types = collect_types(files);
    let mut queue: Vec<String> = collect_roots(files, &types).into_iter().collect();
    let mut seen: HashSet<String> = queue.iter().cloned().collect();
    while let Some(name) = queue.pop() {
        let Some(info) = types.get(&name) else { continue };
        let f = &files[info.file];
        for (id, line) in &info.fields {
            if NON_SEND.contains(&id.as_str()) {
                if !f.allowed("send-hygiene", *line) {
                    out.push(viol(
                        f,
                        *line,
                        "send-hygiene",
                        format!(
                            "`{name}` holds non-Send `{id}` but is reachable from an \
                             Arc/channel thread boundary — the Arc page-pool migration \
                             gate; move the state or annotate with \
                             `hass-lint: allow(send-hygiene)`"
                        ),
                    ));
                }
            } else if types.contains_key(id) && seen.insert(id.clone()) {
                queue.push(id.clone());
            }
        }
    }
    // direct captures: Rc/Cell/RefCell named inside a spawn(...) span
    for f in files {
        let t = &f.toks;
        for i in 0..t.len() {
            if t[i].kind != Kind::Ident || t[i].text != "spawn" || tx(t, i + 1) != "(" {
                continue;
            }
            let mut d = 0i64;
            let mut j = i + 1;
            while j < t.len() {
                match tx(t, j) {
                    "(" => d += 1,
                    ")" => {
                        d -= 1;
                        if d == 0 {
                            break;
                        }
                    }
                    _ => {
                        if t[j].kind == Kind::Ident
                            && NON_SEND.contains(&t[j].text.as_str())
                            && !f.allowed("send-hygiene", t[j].line)
                        {
                            out.push(viol(
                                f,
                                t[j].line,
                                "send-hygiene",
                                format!("`{}` named inside a spawn(...) closure", t[j].text),
                            ));
                        }
                    }
                }
                j += 1;
            }
        }
    }
}

// ---------------------------------------------------------------------
// R3 `stamp-discipline`
// ---------------------------------------------------------------------
// In `kvcache/mod.rs`: a fn carrying the `#[hass::mutates_storage]` doc
// marker must reach a stamp bump on its write path (`page_mut` /
// `dedup_page*` / `next_stamp` / `stamp.set`, or a call to another
// marked fn); conversely, any fn inside `impl KvCache` / `impl Page`
// whose body calls `page_mut` or `dedup_page*` must carry the marker.
// The marker is a comment, so it survives into rustdoc without needing
// a real proc-macro.

struct FnInfo {
    name: String,
    line: usize,
    is_pub: bool,
    body: Option<(usize, usize)>,
    impl_target: Option<String>,
}

fn parse_fns(t: &[Tok]) -> Vec<FnInfo> {
    let pairs = brace_pairs(t);
    // impl spans: (target, open brace, close brace)
    let mut impl_spans: Vec<(String, usize, usize)> = Vec::new();
    let mut i = 0usize;
    while i < t.len() {
        if t[i].kind == Kind::Ident && t[i].text == "impl" {
            let mut target: Option<String> = None;
            let mut saw_for = false;
            let mut j = i + 1;
            while j < t.len() && tx(t, j) != "{" && tx(t, j) != ";" {
                if t[j].kind == Kind::Ident {
                    if t[j].text == "for" {
                        saw_for = true;
                    } else if saw_for {
                        target = Some(t[j].text.clone());
                        saw_for = false;
                    } else if target.is_none() {
                        target = Some(t[j].text.clone());
                    }
                }
                j += 1;
            }
            if j < t.len() && tx(t, j) == "{" {
                if let (Some(tg), Some(&close)) = (target, pairs.get(&j)) {
                    impl_spans.push((tg, j, close));
                }
            }
            i = j + 1;
            continue;
        }
        i += 1;
    }
    let mut fns: Vec<FnInfo> = Vec::new();
    for i in 0..t.len() {
        if t[i].kind != Kind::Ident || t[i].text != "fn" {
            continue;
        }
        let Some(name_tok) = t.get(i + 1) else { continue };
        if name_tok.kind != Kind::Ident {
            continue;
        }
        // visibility: scan back a handful of tokens for `pub` without
        // crossing a statement boundary
        let mut is_pub = false;
        let mut k = i;
        for _ in 0..6 {
            if k == 0 {
                break;
            }
            k -= 1;
            match tx(t, k) {
                "pub" => {
                    is_pub = true;
                    break;
                }
                "{" | "}" | ";" => break,
                _ => {}
            }
        }
        // body: first `{` at bracket depth 0 before a `;`
        let mut body: Option<(usize, usize)> = None;
        let mut depth = 0i64;
        let mut j = i + 2;
        while j < t.len() {
            match tx(t, j) {
                "(" | "[" => depth += 1,
                ")" | "]" => depth -= 1,
                "{" if depth == 0 => {
                    if let Some(&close) = pairs.get(&j) {
                        body = Some((j, close));
                    }
                    break;
                }
                ";" if depth == 0 => break,
                _ => {}
            }
            j += 1;
        }
        let impl_target = impl_spans
            .iter()
            .filter(|(_, o, c)| *o < i && i < *c)
            .min_by_key(|(_, o, c)| c - o)
            .map(|(tg, _, _)| tg.clone());
        fns.push(FnInfo { name: name_tok.text.clone(), line: t[i].line, is_pub, body, impl_target });
    }
    fns
}

const STORAGE_MARKER: &str = "#[hass::mutates_storage]";

fn body_bumps_stamp(t: &[Tok], body: (usize, usize), marked_names: &HashSet<String>) -> bool {
    let (open, close) = body;
    for k in (open + 1)..close {
        if t[k].kind != Kind::Ident {
            continue;
        }
        let s = t[k].text.as_str();
        if s == "page_mut" || s == "next_stamp" || s.starts_with("dedup_page") {
            return true;
        }
        if s == "stamp" && tx(t, k + 1) == "." && tx(t, k + 2) == "set" {
            return true;
        }
        if marked_names.contains(s) {
            return true;
        }
    }
    false
}

fn body_writes_storage(t: &[Tok], body: (usize, usize)) -> bool {
    let (open, close) = body;
    for k in (open + 1)..close {
        if t[k].kind == Kind::Ident
            && (t[k].text == "page_mut" || t[k].text.starts_with("dedup_page"))
        {
            return true;
        }
    }
    false
}

fn r3_stamp_discipline(f: &SourceFile, out: &mut Vec<Violation>) {
    if !f.path.ends_with("kvcache/mod.rs") {
        return;
    }
    let t = &f.toks;
    let fns = parse_fns(t);
    // marker -> nearest following fn (within a short doc-comment window)
    let mut marked: HashSet<usize> = HashSet::new();
    for c in f.comments.iter().filter(|c| c.text.contains(STORAGE_MARKER)) {
        let target = fns
            .iter()
            .enumerate()
            .filter(|(_, fi)| fi.line >= c.line && fi.line <= c.line + 12)
            .min_by_key(|(_, fi)| fi.line)
            .map(|(idx, _)| idx);
        match target {
            Some(idx) => {
                marked.insert(idx);
            }
            None => out.push(viol(
                f,
                c.line,
                "stamp-discipline",
                "`#[hass::mutates_storage]` marker with no fn in the next 12 lines".to_string(),
            )),
        }
    }
    let marked_names: HashSet<String> =
        marked.iter().map(|&idx| fns[idx].name.clone()).collect();
    for (idx, fi) in fns.iter().enumerate() {
        let on_storage = matches!(fi.impl_target.as_deref(), Some("KvCache") | Some("Page"));
        if !on_storage {
            continue;
        }
        let Some(body) = fi.body else { continue };
        if marked.contains(&idx) && !body_bumps_stamp(t, body, &marked_names) {
            if !f.allowed("stamp-discipline", fi.line) {
                out.push(viol(
                    f,
                    fi.line,
                    "stamp-discipline",
                    format!(
                        "`{}` is marked #[hass::mutates_storage] but its body never \
                         reaches a stamp bump (page_mut / dedup_page / next_stamp / \
                         stamp.set) — a write without a bump lets (id,stamp) alias \
                         two different page contents",
                        fi.name
                    ),
                ));
            }
        }
        if !marked.contains(&idx)
            && fi.is_pub
            && body_writes_storage(t, body)
            && !f.allowed("stamp-discipline", fi.line)
        {
            out.push(viol(
                f,
                fi.line,
                "stamp-discipline",
                format!(
                    "pub fn `{}` writes page storage (page_mut / dedup_page) but lacks \
                     the #[hass::mutates_storage] doc marker",
                    fi.name
                ),
            ));
        }
    }
}

// ---------------------------------------------------------------------
// R4 `wire-drift`
// ---------------------------------------------------------------------
// EMIT keys: `("key",` tuple patterns in server/scheduler/main (the
// Json::obj builder convention) plus `"key":` sequences embedded inside
// string literals (raw request lines like `{"stats":true}`).  READ keys:
// `.get("key")` / `.str_at("key")` / `.usize_at` / `.f64_at` / `.u64_at`
// / `.bool_at`.  Every read key must be emitted somewhere, else the
// client is parsing a key the server no longer sends.

fn embedded_keys(content: &str, keys: &mut HashSet<String>) {
    let b: Vec<char> = content.chars().collect();
    let mut i = 0usize;
    while i < b.len() {
        if b[i] == '"' || (b[i] == '\\' && i + 1 < b.len() && b[i + 1] == '"') {
            let mut j = if b[i] == '"' { i + 1 } else { i + 2 };
            let start = j;
            while j < b.len() && (b[j].is_alphanumeric() || b[j] == '_') {
                j += 1;
            }
            if j > start {
                // closing quote (possibly escaped) then ':'
                let mut k = j;
                if k < b.len() && b[k] == '\\' {
                    k += 1;
                }
                if k < b.len() && b[k] == '"' {
                    k += 1;
                    if k < b.len() && b[k] == ':' {
                        keys.insert(b[start..j].iter().collect());
                        i = k;
                        continue;
                    }
                }
            }
            i = j.max(i + 1);
            continue;
        }
        i += 1;
    }
}

const READ_FNS: [&str; 6] = ["get", "str_at", "usize_at", "f64_at", "u64_at", "bool_at"];

fn r4_wire_drift(files: &[SourceFile], out: &mut Vec<Violation>) {
    let mut emitted: HashSet<String> = HashSet::new();
    for f in files {
        if !(is_wire_file(&f.path) || f.path.ends_with("scheduler/mod.rs")) {
            continue;
        }
        let t = &f.toks;
        for i in 0..t.len() {
            if tx(t, i) == "("
                && t.get(i + 1).map(|k| k.kind == Kind::Str).unwrap_or(false)
                && tx(t, i + 2) == ","
            {
                emitted.insert(t[i + 1].text.clone());
            }
            if t[i].kind == Kind::Str {
                embedded_keys(&t[i].text, &mut emitted);
            }
        }
    }
    for f in files {
        if !is_wire_file(&f.path) {
            continue;
        }
        let t = &f.toks;
        for i in 0..t.len() {
            if t[i].kind == Kind::Ident
                && READ_FNS.contains(&t[i].text.as_str())
                && tx(t, i.wrapping_sub(1)) == "."
                && tx(t, i + 1) == "("
                && t.get(i + 2).map(|k| k.kind == Kind::Str).unwrap_or(false)
                && tx(t, i + 3) == ")"
            {
                let key = &t[i + 2].text;
                if !emitted.contains(key) && !f.allowed("wire-drift", t[i].line) {
                    out.push(viol(
                        f,
                        t[i].line,
                        "wire-drift",
                        format!(
                            "wire key \"{key}\" is parsed here but never emitted by \
                             server/scheduler — protocol drift"
                        ),
                    ));
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// R5 `panic-isolation`
// ---------------------------------------------------------------------
// Every `spawn(...)` argument span in scheduler/server must mention
// `catch_unwind`: a worker or writer-pump thread that panics bare takes
// its queue down silently.

fn r5_panic_isolation(f: &SourceFile, out: &mut Vec<Violation>) {
    if !is_thread_file(&f.path) {
        return;
    }
    let t = &f.toks;
    for i in 0..t.len() {
        if t[i].kind != Kind::Ident || t[i].text != "spawn" || tx(t, i + 1) != "(" {
            continue;
        }
        let mut d = 0i64;
        let mut j = i + 1;
        let mut has_catch = false;
        while j < t.len() {
            match tx(t, j) {
                "(" => d += 1,
                ")" => {
                    d -= 1;
                    if d == 0 {
                        break;
                    }
                }
                "catch_unwind" => has_catch = true,
                _ => {}
            }
            j += 1;
        }
        if !has_catch && !f.allowed("panic-isolation", t[i].line) {
            out.push(viol(
                f,
                t[i].line,
                "panic-isolation",
                "spawned thread body lacks catch_unwind — a panic here silently kills \
                 the worker/pump loop"
                    .to_string(),
            ));
        }
    }
}

// ---------------------------------------------------------------------
// R-unsafe `unsafe-comment`
// ---------------------------------------------------------------------
// Every `unsafe` token needs a comment containing `SAFETY:` on the same
// line or within the 3 lines above.

fn r_unsafe_comment(f: &SourceFile, out: &mut Vec<Violation>) {
    for tok in f.toks.iter().filter(|t| t.kind == Kind::Ident && t.text == "unsafe") {
        let line = tok.line;
        let documented = f
            .comments
            .iter()
            .any(|c| c.text.contains("SAFETY:") && c.line <= line && c.line + 3 >= line);
        if !documented && !f.allowed("unsafe-comment", line) {
            out.push(viol(
                f,
                line,
                "unsafe-comment",
                "unsafe block without a `// SAFETY:` comment in the preceding 3 lines"
                    .to_string(),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::run_sources;

    fn rules_fired(sources: &[(&str, &str)]) -> Vec<String> {
        run_sources(sources).into_iter().map(|v| v.rule).collect()
    }

    // ---- R1 ----

    #[test]
    fn r1_fires_on_unwrap_in_fused_path() {
        let fired = rules_fired(&[(
            "rust/src/scheduler/mod.rs",
            "fn f(x: Option<u32>) -> u32 { x.unwrap() }",
        )]);
        assert_eq!(fired, vec!["no-unwrap"]);
    }

    #[test]
    fn r1_fires_on_expect_and_call_indexing() {
        let v = run_sources(&[(
            "rust/src/kvcache/mod.rs",
            "fn f(x: Option<u32>) -> u32 { x.expect(\"boom\") }\n\
             fn g() -> u32 { h()[0] }\nfn h() -> Vec<u32> { vec![] }",
        )]);
        assert_eq!(v.len(), 2);
        assert!(v.iter().all(|v| v.rule == "no-unwrap"));
    }

    #[test]
    fn r1_annotated_does_not_fire() {
        let fired = rules_fired(&[(
            "rust/src/scheduler/mod.rs",
            "fn f(x: Option<u32>) -> u32 {\n\
             // hass-lint: allow(no-unwrap) — x was checked by the caller\n\
             x.unwrap()\n}",
        )]);
        assert!(fired.is_empty(), "{fired:?}");
    }

    #[test]
    fn r1_ignores_non_fused_paths_and_tests() {
        let fired = rules_fired(&[
            ("rust/src/tables/mod.rs", "fn f(x: Option<u32>) -> u32 { x.unwrap() }"),
            (
                "rust/src/scheduler/mod.rs",
                "#[cfg(test)]\nmod tests { fn f(x: Option<u32>) -> u32 { x.unwrap() } }",
            ),
            ("rust/src/kvcache/props.rs", "fn f(x: Option<u32>) -> u32 { x.unwrap() }"),
        ]);
        assert!(fired.is_empty(), "{fired:?}");
    }

    #[test]
    fn r1_leaves_unwrap_or_else_alone() {
        let fired = rules_fired(&[(
            "rust/src/scheduler/mod.rs",
            "fn f(m: &std::sync::Mutex<u32>) -> u32 { \
             *m.lock().unwrap_or_else(|p| p.into_inner()) }",
        )]);
        assert!(fired.is_empty(), "{fired:?}");
    }

    // ---- R2 ----

    #[test]
    fn r2_fires_on_rc_field_behind_arc() {
        let fired = rules_fired(&[(
            "rust/src/anywhere.rs",
            "use std::rc::Rc; use std::sync::Arc;\n\
             struct Inner { p: Rc<u32> }\n\
             struct Outer { inner: Inner }\n\
             fn f(x: Arc<Outer>) { let _ = x; }",
        )]);
        assert_eq!(fired, vec!["send-hygiene"]);
    }

    #[test]
    fn r2_fires_on_cell_in_channel_payload() {
        let fired = rules_fired(&[(
            "rust/src/anywhere.rs",
            "enum Msg { Go(State) }\n\
             struct State { c: std::cell::Cell<u64> }\n\
             fn f(tx: std::sync::mpsc::Sender<Msg>) { let _ = tx; }",
        )]);
        assert_eq!(fired, vec!["send-hygiene"]);
    }

    #[test]
    fn r2_unreachable_rc_is_fine() {
        // Rc in a type never sent across a thread boundary: allowed —
        // this is the kvcache Page today.
        let fired = rules_fired(&[(
            "rust/src/anywhere.rs",
            "struct Page { s: std::cell::Cell<u64> }\n\
             struct Sent { n: u64 }\n\
             fn f(x: std::sync::Arc<Sent>) { let _ = x; }",
        )]);
        assert!(fired.is_empty(), "{fired:?}");
    }

    #[test]
    fn r2_annotated_does_not_fire() {
        let fired = rules_fired(&[(
            "rust/src/anywhere.rs",
            "struct Inner { p: std::rc::Rc<u32> } // hass-lint: allow(send-hygiene) — audited single-thread\n\
             fn f(x: std::sync::Arc<Inner>) { let _ = x; }",
        )]);
        assert!(fired.is_empty(), "{fired:?}");
    }

    #[test]
    fn r2_fires_on_rc_in_spawn_closure() {
        let fired = rules_fired(&[(
            "rust/src/anywhere.rs",
            "fn f() { let r = std::rc::Rc::new(1u32); \
             std::thread::spawn(move || { let _ = Rc::strong_count(&r); }); }",
        )]);
        assert_eq!(fired, vec!["send-hygiene"]);
    }

    // ---- R3 ----

    #[test]
    fn r3_fires_on_marked_fn_without_bump() {
        let fired = rules_fired(&[(
            "rust/src/kvcache/mod.rs",
            "struct KvCache { n: usize }\n\
             impl KvCache {\n\
             /// #[hass::mutates_storage]\n\
             pub fn touch(&mut self) { self.n += 1; }\n\
             }",
        )]);
        assert_eq!(fired, vec!["stamp-discipline"]);
    }

    #[test]
    fn r3_fires_on_unmarked_writer() {
        let fired = rules_fired(&[(
            "rust/src/kvcache/mod.rs",
            "struct KvCache { n: usize }\n\
             impl KvCache {\n\
             fn page_mut(&mut self) -> &mut usize { &mut self.n }\n\
             pub fn write(&mut self) { *self.page_mut() = 3; }\n\
             }",
        )]);
        assert_eq!(fired, vec!["stamp-discipline"]);
    }

    #[test]
    fn r3_marked_writer_with_bump_is_clean() {
        let fired = rules_fired(&[(
            "rust/src/kvcache/mod.rs",
            "struct KvCache { n: usize }\n\
             impl KvCache {\n\
             fn page_mut(&mut self) -> &mut usize { &mut self.n }\n\
             /// #[hass::mutates_storage]\n\
             pub fn write(&mut self) { *self.page_mut() = 3; }\n\
             }",
        )]);
        assert!(fired.is_empty(), "{fired:?}");
    }

    #[test]
    fn r3_only_applies_to_kvcache() {
        let fired = rules_fired(&[(
            "rust/src/engine/sessions.rs",
            "struct KvCache { n: usize }\n\
             impl KvCache { fn page_mut(&mut self) {} pub fn w(&mut self) { self.page_mut(); } }",
        )]);
        assert!(fired.is_empty(), "{fired:?}");
    }

    // ---- R4 ----

    #[test]
    fn r4_fires_on_parsed_but_never_emitted_key() {
        let fired = rules_fired(&[(
            "rust/src/server/mod.rs",
            "fn parse(j: &Json) { let _ = j.str_at(\"promt\"); }\n\
             fn emit() -> Json { Json::obj(vec![(\"prompt\", Json::Bool(true))]) }",
        )]);
        assert_eq!(fired, vec!["wire-drift"]);
    }

    #[test]
    fn r4_embedded_raw_string_counts_as_emit() {
        let fired = rules_fired(&[(
            "rust/src/server/mod.rs",
            "fn stats(c: &mut Client) { c.send(r#\"{\"stats\":true}\"#); }\n\
             fn parse(j: &Json) { let _ = j.get(\"stats\"); }",
        )]);
        assert!(fired.is_empty(), "{fired:?}");
    }

    #[test]
    fn r4_format_escaped_key_counts_as_emit() {
        let fired = rules_fired(&[(
            "rust/src/server/mod.rs",
            "fn cancel(id: u64) -> String { format!(\"{{\\\"cancel\\\":{id}}}\") }\n\
             fn parse(j: &Json) { let _ = j.get(\"cancel\"); }",
        )]);
        assert!(fired.is_empty(), "{fired:?}");
    }

    #[test]
    fn r4_ignores_non_wire_files() {
        let fired = rules_fired(&[(
            "rust/src/util/json.rs",
            "fn f(j: &Json) { let _ = j.get(\"whatever\"); }",
        )]);
        assert!(fired.is_empty(), "{fired:?}");
    }

    // ---- R5 ----

    #[test]
    fn r5_fires_on_bare_spawn() {
        let fired = rules_fired(&[(
            "rust/src/server/mod.rs",
            "fn f() { std::thread::spawn(move || { loop {} }); }",
        )]);
        assert_eq!(fired, vec!["panic-isolation"]);
    }

    #[test]
    fn r5_catch_unwind_in_span_is_clean() {
        let fired = rules_fired(&[(
            "rust/src/scheduler/mod.rs",
            "fn f() { std::thread::spawn(move || { \
             let _ = std::panic::catch_unwind(|| work()); }); }\nfn work() {}",
        )]);
        assert!(fired.is_empty(), "{fired:?}");
    }

    #[test]
    fn r5_annotated_does_not_fire() {
        let fired = rules_fired(&[(
            "rust/src/server/mod.rs",
            "fn f() {\n\
             // hass-lint: allow(panic-isolation) — joined immediately below\n\
             std::thread::spawn(move || { loop {} });\n}",
        )]);
        assert!(fired.is_empty(), "{fired:?}");
    }

    // ---- R-unsafe ----

    #[test]
    fn unsafe_without_safety_comment_fires() {
        let fired = rules_fired(&[(
            "rust/src/runtime/tensor.rs",
            "fn f(p: *const u8) -> u8 { unsafe { *p } }",
        )]);
        assert_eq!(fired, vec!["unsafe-comment"]);
    }

    #[test]
    fn unsafe_with_safety_comment_is_clean() {
        let fired = rules_fired(&[(
            "rust/src/runtime/tensor.rs",
            "fn f(p: *const u8) -> u8 {\n\
             // SAFETY: caller guarantees p is valid for reads\n\
             unsafe { *p }\n}",
        )]);
        assert!(fired.is_empty(), "{fired:?}");
    }
}
