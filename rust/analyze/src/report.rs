//! Report rendering (text / json / github) and the findings baseline.
//!
//! The baseline lets a new rule land with pre-existing findings
//! grandfathered: `--baseline baseline.json` filters out any finding
//! whose fingerprint is listed, so CI fails only on *new* findings.
//! Fingerprints are line-number-insensitive (file + rule + message), so
//! unrelated edits that shift code don't invalidate the baseline.
//! `--update-baseline` rewrites the file from the current findings,
//! preserving the recorded justification (`why`) of entries that
//! survive; new entries get a TODO justification that a reviewer must
//! replace.

use crate::Violation;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Format {
    Text,
    Json,
    Github,
}

impl Format {
    pub fn parse(s: &str) -> Option<Format> {
        match s {
            "text" => Some(Format::Text),
            "json" => Some(Format::Json),
            "github" => Some(Format::Github),
            _ => None,
        }
    }
}

/// Line-insensitive identity of a finding, used for baseline matching.
pub fn fingerprint(v: &Violation) -> String {
    let msg: String = v.msg.split_whitespace().collect::<Vec<_>>().join(" ");
    format!("{}|{}|{}", v.file, v.rule, msg)
}

pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn json_unescape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut it = s.chars();
    while let Some(c) = it.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match it.next() {
            Some('n') => out.push('\n'),
            Some('t') => out.push('\t'),
            Some('r') => out.push('\r'),
            Some('u') => {
                let hex: String = (0..4).filter_map(|_| it.next()).collect();
                if let Some(c) = u32::from_str_radix(&hex, 16).ok().and_then(char::from_u32) {
                    out.push(c);
                }
            }
            Some(c) => out.push(c),
            None => {}
        }
    }
    out
}

/// Render the given findings in `format`.  `suppressed` is the number of
/// baselined findings filtered out (surfaced in the summary so a
/// "clean" run that leans on the baseline says so).
pub fn render(viols: &[Violation], format: Format, files_scanned: usize, suppressed: usize) -> String {
    match format {
        Format::Text => {
            let mut out = String::new();
            for v in viols {
                out.push_str(&format!("{}:{}: [{}] {}\n", v.file, v.line, v.rule, v.msg));
                for w in &v.witness {
                    out.push_str(&format!("    via {w}\n"));
                }
            }
            out.push_str(&format!(
                "hass-analyze: {} file(s) scanned, {} violation(s){}\n",
                files_scanned,
                viols.len(),
                if suppressed > 0 { format!(", {suppressed} baselined") } else { String::new() }
            ));
            out
        }
        Format::Json => {
            let mut out = String::from("{\n");
            out.push_str(&format!("  \"files_scanned\": {files_scanned},\n"));
            out.push_str(&format!("  \"baselined\": {suppressed},\n"));
            out.push_str("  \"findings\": [");
            for (i, v) in viols.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str("\n    {");
                out.push_str(&format!("\"file\": \"{}\", ", json_escape(&v.file)));
                out.push_str(&format!("\"line\": {}, ", v.line));
                out.push_str(&format!("\"rule\": \"{}\", ", json_escape(&v.rule)));
                out.push_str(&format!("\"severity\": \"{}\", ", v.severity));
                out.push_str(&format!("\"msg\": \"{}\", ", json_escape(&v.msg)));
                out.push_str("\"witness\": [");
                for (j, w) in v.witness.iter().enumerate() {
                    if j > 0 {
                        out.push_str(", ");
                    }
                    out.push_str(&format!("\"{}\"", json_escape(w)));
                }
                out.push_str("]}");
            }
            out.push_str(if viols.is_empty() { "]\n}\n" } else { "\n  ]\n}\n" });
            out
        }
        Format::Github => {
            // ::error file=...,line=...::message  (newline escape per the
            // workflow-command syntax; witness chain folded in)
            let mut out = String::new();
            for v in viols {
                let level = if v.severity == "warning" { "warning" } else { "error" };
                let mut msg = format!("[{}] {}", v.rule, v.msg);
                for w in &v.witness {
                    msg.push_str(&format!("%0A  via {w}"));
                }
                let msg = msg.replace('\n', "%0A").replace('\r', "%0D");
                out.push_str(&format!(
                    "::{level} file={},line={}::{}\n",
                    v.file, v.line, msg
                ));
            }
            out
        }
    }
}

/// A reviewed set of grandfathered findings.
#[derive(Default)]
pub struct Baseline {
    /// (fingerprint, justification), in file order.
    pub entries: Vec<(String, String)>,
}

impl Baseline {
    pub fn contains(&self, key: &str) -> bool {
        self.entries.iter().any(|(k, _)| k == key)
    }

    pub fn why(&self, key: &str) -> Option<&str> {
        self.entries.iter().find(|(k, _)| k == key).map(|(_, w)| w.as_str())
    }

    /// Parse the baseline file.  The format is the JSON this module
    /// writes; the parser is a small string-field scanner (the analyzer
    /// is dependency-free), tolerant of whitespace and ordering but not
    /// of non-string keys.
    pub fn parse(src: &str) -> Baseline {
        let mut entries: Vec<(String, String)> = Vec::new();
        let b: Vec<char> = src.chars().collect();
        let mut i = 0usize;
        let mut pending_key: Option<String> = None;
        while i < b.len() {
            if b[i] != '"' {
                i += 1;
                continue;
            }
            // scan one string literal
            let start = i + 1;
            let mut j = start;
            while j < b.len() && b[j] != '"' {
                if b[j] == '\\' {
                    j += 1;
                }
                j += 1;
            }
            let raw: String = b[start..j.min(b.len())].iter().collect();
            i = j + 1;
            // is this a field name followed by `:`?
            let mut k = i;
            while k < b.len() && b[k].is_whitespace() {
                k += 1;
            }
            let is_field = k < b.len() && b[k] == ':';
            if is_field && (raw == "key" || raw == "why") {
                // read the value string
                let mut m = k + 1;
                while m < b.len() && b[m].is_whitespace() {
                    m += 1;
                }
                if m < b.len() && b[m] == '"' {
                    let vstart = m + 1;
                    let mut n = vstart;
                    while n < b.len() && b[n] != '"' {
                        if b[n] == '\\' {
                            n += 1;
                        }
                        n += 1;
                    }
                    let val = json_unescape(&b[vstart..n.min(b.len())].iter().collect::<String>());
                    i = n + 1;
                    if raw == "key" {
                        if let Some(prev) = pending_key.take() {
                            entries.push((prev, String::new()));
                        }
                        pending_key = Some(val);
                    } else if let Some(key) = pending_key.take() {
                        entries.push((key, val));
                    }
                }
            }
        }
        if let Some(prev) = pending_key.take() {
            entries.push((prev, String::new()));
        }
        Baseline { entries }
    }

    /// Serialize a baseline covering exactly `viols`, preserving the
    /// `why` of entries already present in `self`.
    pub fn render_updated(&self, viols: &[Violation]) -> String {
        let mut seen: Vec<String> = Vec::new();
        let mut out = String::from("{\n  \"version\": 1,\n  \"findings\": [");
        let mut first = true;
        for v in viols {
            let key = fingerprint(v);
            if seen.contains(&key) {
                continue;
            }
            let why = self
                .why(&key)
                .filter(|w| !w.is_empty())
                .unwrap_or("TODO: justify this finding or fix it");
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(&format!(
                "\n    {{\"key\": \"{}\",\n     \"why\": \"{}\"}}",
                json_escape(&key),
                json_escape(why)
            ));
            seen.push(key);
        }
        out.push_str(if first { "]\n}\n" } else { "\n  ]\n}\n" });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(file: &str, rule: &str, msg: &str) -> Violation {
        Violation {
            file: file.to_string(),
            line: 7,
            rule: rule.to_string(),
            severity: "error".to_string(),
            msg: msg.to_string(),
            witness: vec!["a.rs:1: f -> g".to_string()],
        }
    }

    #[test]
    fn baseline_roundtrip_preserves_why() {
        let viols = vec![v("a.rs", "wire-dead", "wire key \"x\" dead"), v("b.rs", "lock-order", "cycle")];
        let empty = Baseline::default();
        let text = empty.render_updated(&viols);
        let parsed = Baseline::parse(&text);
        assert_eq!(parsed.entries.len(), 2);
        assert!(parsed.contains(&fingerprint(&viols[0])));
        assert_eq!(parsed.why(&fingerprint(&viols[0])), Some("TODO: justify this finding or fix it"));
        // hand-edit the why, re-update: the edit survives
        let edited = text.replace("TODO: justify this finding or fix it", "reviewed 2026-08: consumed off-wire");
        let parsed = Baseline::parse(&edited);
        let text2 = parsed.render_updated(&viols);
        assert!(text2.contains("reviewed 2026-08: consumed off-wire"));
    }

    #[test]
    fn fingerprint_is_line_insensitive() {
        let mut a = v("a.rs", "r", "same   msg");
        let b = v("a.rs", "r", "same msg");
        a.line = 99;
        assert_eq!(fingerprint(&a), fingerprint(&b));
    }

    #[test]
    fn json_render_escapes() {
        let viols = vec![v("a.rs", "r", "key \"x\"\nnext")];
        let s = render(&viols, Format::Json, 3, 1);
        assert!(s.contains("\\\"x\\\"\\nnext"));
        assert!(s.contains("\"files_scanned\": 3"));
        assert!(s.contains("\"baselined\": 1"));
        assert!(s.contains("\"witness\": [\"a.rs:1: f -> g\"]"));
    }

    #[test]
    fn github_render_format() {
        let mut w = v("rust/src/x.rs", "lock-order", "cycle A -> B -> A");
        w.severity = "warning".to_string();
        let s = render(&[w], Format::Github, 1, 0);
        assert!(s.starts_with("::warning file=rust/src/x.rs,line=7::[lock-order] cycle A -> B -> A%0A  via a.rs:1: f -> g\n"), "{s}");
    }
}
