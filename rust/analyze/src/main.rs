//! `hass-analyze <paths...>` — lint the HASS sources.
//!
//! With no arguments it scans `rust/src` (run from the repo root).
//! Exit code 0 = clean, 1 = violations, 2 = I/O error.

fn main() {
    let paths: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(hass_analyze::run_cli(&paths));
}
