//! `hass-analyze [--format text|json|github] [--baseline <file>]
//! [--update-baseline] <paths...>` — lint the HASS sources.
//!
//! With no paths it scans `rust/src` (run from the repo root).  Exit
//! code 0 = clean / baseline updated, 1 = new violations, 2 = I/O error
//! or bad arguments.

fn main() {
    let paths: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(hass_analyze::run_cli(&paths));
}
