//! Whole-crate item resolution: the item graph the interprocedural rules
//! and the call graph are built on.
//!
//! From every [`SourceFile`] this extracts:
//!
//! * `use` aliases — a per-file map from local name to the canonical
//!   `::`-joined path, so rules can ask "is `Shared` really
//!   `std::rc::Rc`?" instead of string-matching bare identifiers;
//! * type items — structs, enums, and `type` aliases, each with the
//!   identifiers appearing in field-type position (the edges of the type
//!   graph R2/R8 walk);
//! * fn items — name, visibility, enclosing `impl` target, body token
//!   span, parameter bindings with their type identifiers, return-type
//!   identifiers, and whether the fn carries the
//!   `#[hass::mutates_storage]` doc marker.
//!
//! Everything here is a lexical approximation (see `lexer.rs`): no
//! hygiene, no generics instantiation, no trait solving.  The graph errs
//! toward over-approximation (more edges, more type links), which for a
//! lint means erring toward reporting; each rule documents where that
//! matters.

use std::collections::HashMap;

use crate::lexer::{Kind, Tok};
use crate::SourceFile;

/// The storage-write doc marker enforced by `stamp-discipline` (a
/// comment convention, so it survives into rustdoc without a real
/// proc-macro).
pub const STORAGE_MARKER: &str = "#[hass::mutates_storage]";

/// A marker must sit within this many lines above its fn (doc comment
/// block length budget).
pub const MARKER_WINDOW: usize = 12;

pub fn tx(t: &[Tok], i: usize) -> &str {
    t.get(i).map(|k| k.text.as_str()).unwrap_or("")
}

/// Matching `}` for every `{` (token indices).
pub fn brace_pairs(t: &[Tok]) -> HashMap<usize, usize> {
    let mut stack: Vec<usize> = Vec::new();
    let mut map: HashMap<usize, usize> = HashMap::new();
    for (i, tk) in t.iter().enumerate() {
        match tk.text.as_str() {
            "{" => stack.push(i),
            "}" => {
                if let Some(o) = stack.pop() {
                    map.insert(o, i);
                }
            }
            _ => {}
        }
    }
    map
}

#[derive(Debug)]
pub struct FnItem {
    pub name: String,
    /// Index into the `files` slice the graph was built from.
    pub file: usize,
    pub line: usize,
    pub is_pub: bool,
    /// Innermost enclosing `impl` target type, if any.
    pub impl_target: Option<String>,
    /// `{`..`}` token span of the body (absent for trait-decl fns).
    pub body: Option<(usize, usize)>,
    /// Parameter bindings: (binding name, identifiers in type position).
    pub params: Vec<(String, Vec<String>)>,
    /// Identifiers in return-type position.
    pub ret: Vec<String>,
    /// Carries the `#[hass::mutates_storage]` marker.
    pub marked: bool,
}

impl FnItem {
    /// `Target::name` when inside an impl, else just `name` — the frame
    /// label used in witness chains.
    pub fn qname(&self) -> String {
        match &self.impl_target {
            Some(t) => format!("{t}::{}", self.name),
            None => self.name.clone(),
        }
    }
}

pub struct TypeItem {
    pub file: usize,
    pub line: usize,
    /// Identifiers in field-type position (structs/enums) or on the RHS
    /// (type aliases), with the line they sit on.
    pub fields: Vec<(String, usize)>,
}

pub struct ItemGraph {
    pub fns: Vec<FnItem>,
    pub types: HashMap<String, TypeItem>,
    /// Per file: local name -> canonical `::`-joined path from `use`.
    pub aliases: Vec<HashMap<String, String>>,
    /// fn name -> indices into `fns`.
    pub by_name: HashMap<String, Vec<usize>>,
    /// `#[hass::mutates_storage]` markers with no fn in the next
    /// [`MARKER_WINDOW`] lines: (file, line).
    pub dangling_markers: Vec<(usize, usize)>,
}

impl ItemGraph {
    /// Canonical `::`-joined path of `name` as seen from `file`
    /// (resolved through that file's `use` aliases; falls back to the
    /// bare name).
    pub fn canon<'a>(&'a self, file: usize, name: &'a str) -> &'a str {
        self.aliases
            .get(file)
            .and_then(|m| m.get(name))
            .map(String::as_str)
            .unwrap_or(name)
    }

    pub fn build(files: &[SourceFile]) -> ItemGraph {
        let mut fns: Vec<FnItem> = Vec::new();
        let mut types: HashMap<String, TypeItem> = HashMap::new();
        let mut aliases: Vec<HashMap<String, String>> = Vec::new();
        let mut dangling: Vec<(usize, usize)> = Vec::new();
        for (fi, f) in files.iter().enumerate() {
            let t = &f.toks;
            let pairs = brace_pairs(t);
            aliases.push(parse_uses(t));
            collect_types(fi, t, &pairs, &mut types);
            let first = fns.len();
            parse_fns(fi, t, &pairs, &mut fns);
            // attach markers: nearest following fn within the window
            for c in f.comments.iter().filter(|c| c.text.contains(STORAGE_MARKER)) {
                let target = fns[first..]
                    .iter_mut()
                    .filter(|x| x.line >= c.line && x.line <= c.line + MARKER_WINDOW)
                    .min_by_key(|x| x.line);
                match target {
                    Some(x) => x.marked = true,
                    None => dangling.push((fi, c.line)),
                }
            }
        }
        let mut by_name: HashMap<String, Vec<usize>> = HashMap::new();
        for (i, f) in fns.iter().enumerate() {
            by_name.entry(f.name.clone()).or_default().push(i);
        }
        ItemGraph { fns, types, aliases, by_name, dangling_markers: dangling }
    }
}

/// Parse every `use` item in a token stream into local-name -> canonical
/// path entries.  Handles `a::b::C`, `as` renames, nested `{...}` trees,
/// and leading `crate`/`super`/`self` segments; `*` globs are skipped
/// (they bind no local name we can track).
fn parse_uses(t: &[Tok]) -> HashMap<String, String> {
    let mut map = HashMap::new();
    let mut i = 0usize;
    while i < t.len() {
        if t[i].kind == Kind::Ident && t[i].text == "use" {
            i = parse_use_tree(t, i + 1, &[], &mut map);
        } else {
            i += 1;
        }
    }
    map
}

/// Parse one use-tree starting at `i` with the given path `prefix`;
/// returns the index just past it.
fn parse_use_tree(
    t: &[Tok],
    mut i: usize,
    prefix: &[String],
    map: &mut HashMap<String, String>,
) -> usize {
    let mut segs: Vec<String> = prefix.to_vec();
    let mut bound = false;
    loop {
        match tx(t, i) {
            "{" => {
                // group: recurse per comma-separated subtree
                i += 1;
                loop {
                    i = parse_use_tree(t, i, &segs, map);
                    match tx(t, i) {
                        "," => i += 1,
                        "}" => return i + 1,
                        _ => return i, // malformed / EOF: bail
                    }
                }
            }
            ":" => {
                i += 1; // `::` path separator (two Punct tokens)
                if tx(t, i) == ":" {
                    i += 1;
                }
            }
            "*" => return i + 1, // glob: nothing to bind
            ";" | "," | "}" | "" => {
                if !bound {
                    bind(map, &segs, None);
                }
                return if tx(t, i) == ";" { i + 1 } else { i };
            }
            "as" => {
                let alias = tx(t, i + 1).to_string();
                bind(map, &segs, Some(alias));
                bound = true;
                i += 2;
                // next loop turn handles the terminator
            }
            _ if t[i].kind == Kind::Ident => {
                segs.push(t[i].text.clone());
                i += 1;
            }
            _ => return i + 1, // unexpected token: resync
        }
    }
}

fn bind(map: &mut HashMap<String, String>, segs: &[String], alias: Option<String>) {
    // `use a::b::{self}` binds `b`; `self`/`crate`/`super` never bind alone
    let mut segs = segs.to_vec();
    if segs.last().map(String::as_str) == Some("self") {
        segs.pop();
    }
    let Some(last) = segs.last() else { return };
    let name = alias.unwrap_or_else(|| last.clone());
    if name == "crate" || name == "super" || name == "self" || name.is_empty() {
        return;
    }
    map.insert(name, segs.join("::"));
}

/// Structs, enums, and `type` aliases, with identifiers in field-type /
/// RHS position.
fn collect_types(
    fi: usize,
    t: &[Tok],
    pairs: &HashMap<usize, usize>,
    map: &mut HashMap<String, TypeItem>,
) {
    let mut i = 0usize;
    while i < t.len() {
        if t[i].kind != Kind::Ident {
            i += 1;
            continue;
        }
        // `type X = RHS;` alias: RHS idents become the fields of X
        if t[i].text == "type"
            && t.get(i + 1).map(|k| k.kind == Kind::Ident).unwrap_or(false)
            && (tx(t, i + 2) == "=" || (tx(t, i + 2) == "<" /* generic alias */))
        {
            let name = t[i + 1].text.clone();
            let line = t[i + 1].line;
            let mut j = i + 2;
            while j < t.len() && tx(t, j) != "=" && tx(t, j) != ";" {
                j += 1;
            }
            let mut fields: Vec<(String, usize)> = Vec::new();
            while j < t.len() && tx(t, j) != ";" {
                if t[j].kind == Kind::Ident {
                    fields.push((t[j].text.clone(), t[j].line));
                }
                j += 1;
            }
            // `type X;` in traits / `let ... type`-free matches: only keep
            // aliases that actually have an RHS
            if !fields.is_empty() {
                map.insert(name, TypeItem { file: fi, line, fields });
            }
            i = j + 1;
            continue;
        }
        if t[i].text != "struct" && t[i].text != "enum" {
            i += 1;
            continue;
        }
        let Some(name) = t.get(i + 1) else { break };
        if name.kind != Kind::Ident {
            i += 1;
            continue;
        }
        // skip generics to the body start: `{`, `(`, or `;`
        let mut angle = 0i64;
        let mut j = i + 2;
        while j < t.len() {
            match tx(t, j) {
                "<" => angle += 1,
                ">" => angle -= 1,
                "{" | "(" | ";" if angle <= 0 => break,
                _ => {}
            }
            j += 1;
        }
        if j >= t.len() || tx(t, j) == ";" {
            i = j + 1;
            continue;
        }
        let (open, close) = if tx(t, j) == "{" {
            match pairs.get(&j) {
                Some(&c) => (j, c),
                None => {
                    i = j + 1;
                    continue;
                }
            }
        } else {
            // tuple struct: match the `)`
            let mut d = 0i64;
            let mut k = j;
            let mut close = j;
            while k < t.len() {
                match tx(t, k) {
                    "(" => d += 1,
                    ")" => {
                        d -= 1;
                        if d == 0 {
                            close = k;
                            break;
                        }
                    }
                    _ => {}
                }
                k += 1;
            }
            (j, close)
        };
        let mut fields: Vec<(String, usize)> = Vec::new();
        for k in (open + 1)..close {
            let tk = &t[k];
            if tk.kind != Kind::Ident {
                continue;
            }
            if matches!(tk.text.as_str(), "pub" | "crate" | "super" | "in" | "dyn" | "mut") {
                continue;
            }
            // `ident :` (single colon) is a field name, not a type
            let single_colon = tx(t, k + 1) == ":" && tx(t, k + 2) != ":";
            if single_colon {
                continue;
            }
            fields.push((tk.text.clone(), tk.line));
        }
        map.insert(name.text.clone(), TypeItem { file: fi, line: name.line, fields });
        i = close + 1;
    }
}

/// Fn items with signatures: visibility, impl target, body span, params
/// (binding name + type idents), and return-type idents.
fn parse_fns(fi: usize, t: &[Tok], pairs: &HashMap<usize, usize>, out: &mut Vec<FnItem>) {
    // impl spans: (target, open brace, close brace)
    let mut impl_spans: Vec<(String, usize, usize)> = Vec::new();
    let mut i = 0usize;
    while i < t.len() {
        if t[i].kind == Kind::Ident && t[i].text == "impl" {
            let mut j = i + 1;
            // skip the generic parameter list `impl<T, U>`
            if tx(t, j) == "<" {
                let mut angle = 0i64;
                while j < t.len() {
                    match tx(t, j) {
                        "<" => angle += 1,
                        ">" => {
                            angle -= 1;
                            if angle == 0 {
                                j += 1;
                                break;
                            }
                        }
                        _ => {}
                    }
                    j += 1;
                }
            }
            let mut target: Option<String> = None;
            let mut saw_for = false;
            while j < t.len() && tx(t, j) != "{" && tx(t, j) != ";" {
                if t[j].kind == Kind::Ident {
                    if t[j].text == "for" {
                        saw_for = true;
                    } else if saw_for {
                        target = Some(t[j].text.clone());
                        saw_for = false;
                    } else if target.is_none() {
                        target = Some(t[j].text.clone());
                    }
                }
                j += 1;
            }
            if j < t.len() && tx(t, j) == "{" {
                if let (Some(tg), Some(&close)) = (target, pairs.get(&j)) {
                    impl_spans.push((tg, j, close));
                }
            }
            i = j + 1;
            continue;
        }
        i += 1;
    }
    for i in 0..t.len() {
        if t[i].kind != Kind::Ident || t[i].text != "fn" {
            continue;
        }
        let Some(name_tok) = t.get(i + 1) else { continue };
        if name_tok.kind != Kind::Ident {
            continue;
        }
        // visibility: scan back a handful of tokens for `pub` without
        // crossing a statement boundary
        let mut is_pub = false;
        let mut k = i;
        for _ in 0..6 {
            if k == 0 {
                break;
            }
            k -= 1;
            match tx(t, k) {
                "pub" => {
                    is_pub = true;
                    break;
                }
                "{" | "}" | ";" => break,
                _ => {}
            }
        }
        // skip fn generics `<...>` to the parameter list
        let mut j = i + 2;
        if tx(t, j) == "<" {
            let mut angle = 0i64;
            while j < t.len() {
                match tx(t, j) {
                    "<" => angle += 1,
                    ">" if tx(t, j.wrapping_sub(1)) != "-" => {
                        angle -= 1;
                        if angle == 0 {
                            j += 1;
                            break;
                        }
                    }
                    _ => {}
                }
                j += 1;
            }
        }
        // parameter list span
        let mut params: Vec<(String, Vec<String>)> = Vec::new();
        let mut params_end = j;
        if tx(t, j) == "(" {
            let mut d = 0i64;
            let mut k = j;
            while k < t.len() {
                match tx(t, k) {
                    "(" | "[" => d += 1,
                    ")" | "]" => {
                        d -= 1;
                        if d == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                k += 1;
            }
            parse_params(t, j + 1, k, &mut params);
            params_end = k + 1;
        }
        // return-type idents: `-> ...` until `{` / `;` / `where`
        let mut ret: Vec<String> = Vec::new();
        let mut j = params_end;
        if tx(t, j) == "-" && tx(t, j + 1) == ">" {
            j += 2;
            let mut d = 0i64;
            while j < t.len() {
                match tx(t, j) {
                    "(" | "[" => d += 1,
                    ")" | "]" => d -= 1,
                    "{" | ";" if d <= 0 => break,
                    "where" if d <= 0 => break,
                    _ => {
                        if t[j].kind == Kind::Ident {
                            ret.push(t[j].text.clone());
                        }
                    }
                }
                j += 1;
            }
        }
        // body: first `{` at bracket depth 0 before a `;`
        let mut body: Option<(usize, usize)> = None;
        let mut depth = 0i64;
        let mut j = i + 2;
        while j < t.len() {
            match tx(t, j) {
                "(" | "[" => depth += 1,
                ")" | "]" => depth -= 1,
                "{" if depth == 0 => {
                    if let Some(&close) = pairs.get(&j) {
                        body = Some((j, close));
                    }
                    break;
                }
                ";" if depth == 0 => break,
                _ => {}
            }
            j += 1;
        }
        let impl_target = impl_spans
            .iter()
            .filter(|(_, o, c)| *o < i && i < *c)
            .min_by_key(|(_, o, c)| c - o)
            .map(|(tg, _, _)| tg.clone());
        out.push(FnItem {
            name: name_tok.text.clone(),
            file: fi,
            line: t[i].line,
            is_pub,
            impl_target,
            body,
            params,
            ret,
            marked: false,
        });
    }
}

/// Split the parameter span `[open, close)` on top-level commas; each
/// chunk `pat: Type` yields (last ident before the single `:`, idents
/// after it).  `self` receivers are skipped.
fn parse_params(t: &[Tok], open: usize, close: usize, out: &mut Vec<(String, Vec<String>)>) {
    let mut chunk_start = open;
    let mut d = 0i64;
    let mut k = open;
    loop {
        let at_end = k >= close;
        let is_split = at_end || (d == 0 && tx(t, k) == ",");
        if is_split {
            let chunk = &t[chunk_start..k.min(close)];
            // the single `:` separating pattern from type (not `::`)
            let colon = chunk.iter().enumerate().position(|(ci, c)| {
                c.text == ":"
                    && chunk.get(ci + 1).map(|n| n.text != ":").unwrap_or(true)
                    && (ci == 0 || chunk[ci - 1].text != ":")
            });
            if let Some(ci) = colon {
                let name = chunk[..ci]
                    .iter()
                    .rev()
                    .find(|c| c.kind == Kind::Ident && c.text != "mut" && c.text != "ref");
                let tys: Vec<String> = chunk[ci + 1..]
                    .iter()
                    .filter(|c| c.kind == Kind::Ident)
                    .map(|c| c.text.clone())
                    .collect();
                if let Some(n) = name {
                    if n.text != "self" {
                        out.push((n.text.clone(), tys));
                    }
                }
            }
            chunk_start = k + 1;
        }
        if at_end {
            break;
        }
        match tx(t, k) {
            "(" | "[" | "{" => d += 1,
            ")" | "]" | "}" => d -= 1,
            _ => {}
        }
        k += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::source_from;

    fn graph(src: &str) -> (Vec<SourceFile>, ItemGraph) {
        let (f, v) = source_from("rust/src/x.rs", src);
        assert!(v.is_empty(), "{v:?}");
        let files = vec![f];
        let g = ItemGraph::build(&files);
        (files, g)
    }

    #[test]
    fn use_aliases_resolve() {
        let (_, g) = graph(
            "use std::rc::Rc as Shared;\n\
             use std::sync::{Arc, mpsc::{Sender, SyncSender as Stx}};\n\
             use crate::kvcache::KvCache;\n",
        );
        assert_eq!(g.canon(0, "Shared"), "std::rc::Rc");
        assert_eq!(g.canon(0, "Arc"), "std::sync::Arc");
        assert_eq!(g.canon(0, "Stx"), "std::sync::mpsc::SyncSender");
        assert_eq!(g.canon(0, "KvCache"), "crate::kvcache::KvCache");
        assert_eq!(g.canon(0, "Unknown"), "Unknown");
    }

    #[test]
    fn use_self_binds_module() {
        let (_, g) = graph("use crate::util::{self, lockorder};\n");
        assert_eq!(g.canon(0, "util"), "crate::util");
        assert_eq!(g.canon(0, "lockorder"), "crate::util::lockorder");
    }

    #[test]
    fn type_alias_fields_feed_type_graph() {
        let (_, g) = graph("type PageRef = std::rc::Rc<Page>;\nstruct Page { n: u32 }\n");
        let fields: Vec<&str> =
            g.types["PageRef"].fields.iter().map(|(s, _)| s.as_str()).collect();
        assert!(fields.contains(&"Rc"));
        assert!(fields.contains(&"Page"));
    }

    #[test]
    fn fn_signatures_parsed() {
        let (_, g) = graph(
            "impl KvCache {\n\
             pub fn write(&mut self, rows: &[Vec<f32>], n: usize) -> Option<PageRef> { None }\n\
             }\n\
             fn helper<T: Clone>(x: T, mut s: String) -> u32 { 0 }\n",
        );
        let w = g.fns.iter().find(|f| f.name == "write").unwrap();
        assert!(w.is_pub);
        assert_eq!(w.impl_target.as_deref(), Some("KvCache"));
        assert_eq!(w.qname(), "KvCache::write");
        assert_eq!(w.params.len(), 2);
        assert_eq!(w.params[0].0, "rows");
        assert!(w.params[0].1.contains(&"Vec".to_string()));
        assert!(w.ret.contains(&"PageRef".to_string()));
        let h = g.fns.iter().find(|f| f.name == "helper").unwrap();
        assert!(!h.is_pub);
        assert_eq!(h.params[1].0, "s");
        assert!(h.params[1].1.contains(&"String".to_string()));
    }

    #[test]
    fn generic_impl_target() {
        let (_, g) = graph("impl<T> Holder<T> { fn get(&self) -> &T { &self.0 } }\nstruct Holder<T>(T);");
        let f = g.fns.iter().find(|f| f.name == "get").unwrap();
        assert_eq!(f.impl_target.as_deref(), Some("Holder"));
    }

    #[test]
    fn marker_attaches_to_following_fn() {
        let (_, g) = graph(
            "impl KvCache {\n\
             /// #[hass::mutates_storage]\n\
             /// Writes rows.\n\
             pub fn write(&mut self) {}\n\
             pub fn read(&self) {}\n\
             }\nstruct KvCache;",
        );
        assert!(g.fns.iter().find(|f| f.name == "write").unwrap().marked);
        assert!(!g.fns.iter().find(|f| f.name == "read").unwrap().marked);
        assert!(g.dangling_markers.is_empty());
    }

    #[test]
    fn dangling_marker_recorded() {
        let (_, g) = graph("/// #[hass::mutates_storage]\nstruct NotAFn;\n");
        assert_eq!(g.dangling_markers, vec![(0, 1)]);
    }
}
