//! A deliberately small Rust lexer: just enough token structure for the
//! `hass-analyze` rules (identifiers, numbers, string contents, single-char
//! punctuation) plus a parallel comment stream with line numbers.
//!
//! It is NOT a full Rust grammar — no macro expansion, no type checking.
//! The rules are written against token *patterns*, which keeps the whole
//! analyzer dependency-free and fast, at the cost of being a lexical
//! approximation.  Where that approximation could misfire, the rule docs
//! in `rules.rs` say so.

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Kind {
    Ident,
    Num,
    /// String literal; `text` holds the *content* (no quotes), with raw /
    /// byte prefixes and `#` guards stripped.  Escapes are left as-is.
    Str,
    Punct,
}

#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    pub line: usize,
}

#[derive(Clone, Debug)]
pub struct Comment {
    /// Full comment text including the `//` / `/*` markers.
    pub text: String,
    /// Line the comment starts on.
    pub line: usize,
}

pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
}

pub fn lex(src: &str) -> Lexed {
    let b: Vec<char> = src.chars().collect();
    let mut i = 0usize;
    let mut line = 1usize;
    let mut toks: Vec<Tok> = Vec::new();
    let mut comments: Vec<Comment> = Vec::new();
    // shebang: `#!...` on the very first line is not Rust tokens — but
    // `#![...]` is an inner attribute and must lex normally
    if b.first() == Some(&'#') && b.get(1) == Some(&'!') && b.get(2) != Some(&'[') {
        while i < b.len() && b[i] != '\n' {
            i += 1;
        }
    }
    while i < b.len() {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // line comment (incl. doc comments)
        if c == '/' && i + 1 < b.len() && b[i + 1] == '/' {
            let start = i;
            while i < b.len() && b[i] != '\n' {
                i += 1;
            }
            comments.push(Comment { text: b[start..i].iter().collect(), line });
            continue;
        }
        // block comment (nesting per Rust)
        if c == '/' && i + 1 < b.len() && b[i + 1] == '*' {
            let start_line = line;
            let start = i;
            i += 2;
            let mut depth = 1usize;
            while i < b.len() && depth > 0 {
                if b[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == '/' && i + 1 < b.len() && b[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && i + 1 < b.len() && b[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            comments.push(Comment { text: b[start..i.min(b.len())].iter().collect(), line: start_line });
            continue;
        }
        // plain string literal
        if c == '"' {
            let sl = line;
            i += 1;
            let start = i;
            while i < b.len() {
                if b[i] == '\\' {
                    i += 2;
                    continue;
                }
                if b[i] == '"' {
                    break;
                }
                if b[i] == '\n' {
                    line += 1;
                }
                i += 1;
            }
            let end = i.min(b.len());
            toks.push(Tok { kind: Kind::Str, text: b[start..end].iter().collect(), line: sl });
            if i < b.len() {
                i += 1; // closing quote
            }
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            if i + 1 < b.len() && (b[i + 1].is_alphabetic() || b[i + 1] == '_') {
                let mut j = i + 1;
                while j < b.len() && (b[j].is_alphanumeric() || b[j] == '_') {
                    j += 1;
                }
                if j < b.len() && b[j] == '\'' {
                    i = j + 1; // char literal like 'a'
                } else {
                    i = j; // lifetime: swallow, emit nothing
                }
                continue;
            }
            // escaped / symbolic char literal
            i += 1;
            while i < b.len() {
                if b[i] == '\\' {
                    i += 2;
                    continue;
                }
                if b[i] == '\'' {
                    i += 1;
                    break;
                }
                if b[i] == '\n' {
                    // malformed; resync at the newline
                    break;
                }
                i += 1;
            }
            continue;
        }
        // identifier (may prefix a raw/byte string)
        if c.is_alphabetic() || c == '_' {
            let start = i;
            while i < b.len() && (b[i].is_alphanumeric() || b[i] == '_') {
                i += 1;
            }
            let text: String = b[start..i].iter().collect();
            let is_str_prefix = matches!(text.as_str(), "r" | "b" | "br" | "rb");
            if is_str_prefix && i < b.len() && (b[i] == '"' || b[i] == '#') {
                let sl = line;
                let raw = text.contains('r');
                let mut hashes = 0usize;
                let mut j = i;
                while j < b.len() && b[j] == '#' {
                    hashes += 1;
                    j += 1;
                }
                if j < b.len() && b[j] == '"' {
                    i = j + 1;
                    let cstart = i;
                    loop {
                        if i >= b.len() {
                            toks.push(Tok {
                                kind: Kind::Str,
                                text: b[cstart..b.len()].iter().collect(),
                                line: sl,
                            });
                            break;
                        }
                        if b[i] == '\n' {
                            line += 1;
                            i += 1;
                            continue;
                        }
                        if !raw && b[i] == '\\' {
                            i += 2;
                            continue;
                        }
                        if b[i] == '"' {
                            let mut k = 0usize;
                            while k < hashes && i + 1 + k < b.len() && b[i + 1 + k] == '#' {
                                k += 1;
                            }
                            if k == hashes {
                                toks.push(Tok {
                                    kind: Kind::Str,
                                    text: b[cstart..i].iter().collect(),
                                    line: sl,
                                });
                                i += 1 + hashes;
                                break;
                            }
                        }
                        i += 1;
                    }
                    continue;
                }
                // `r#ident` raw identifier or stray `#`: fall through,
                // the `#` lexes as punctuation next iteration
            }
            toks.push(Tok { kind: Kind::Ident, text, line });
            continue;
        }
        // number (consume `.` only when a digit follows, so `0..n` stays
        // three tokens and range patterns survive)
        if c.is_ascii_digit() {
            let start = i;
            let radix_prefix = c == '0'
                && matches!(b.get(i + 1), Some(&'x') | Some(&'b') | Some(&'o') | Some(&'X'));
            i += 1;
            while i < b.len() {
                let d = b[i];
                if d.is_alphanumeric() || d == '_' {
                    i += 1;
                    continue;
                }
                if d == '.' && i + 1 < b.len() && b[i + 1].is_ascii_digit() {
                    i += 1;
                    continue;
                }
                // float exponent sign: `1e-5` / `2.5E+10` stay one token
                // (not in hex/binary/octal literals, where `e` is a digit)
                if (d == '+' || d == '-')
                    && !radix_prefix
                    && matches!(b[i - 1], 'e' | 'E')
                    && i + 1 < b.len()
                    && b[i + 1].is_ascii_digit()
                {
                    i += 1;
                    continue;
                }
                break;
            }
            toks.push(Tok { kind: Kind::Num, text: b[start..i].iter().collect(), line });
            continue;
        }
        toks.push(Tok { kind: Kind::Punct, text: c.to_string(), line });
        i += 1;
    }
    Lexed { toks, comments }
}

fn tx(toks: &[Tok], i: usize) -> &str {
    toks.get(i).map(|t| t.text.as_str()).unwrap_or("")
}

/// Drop `#[cfg(test)] mod <name> { ... }` bodies (and skip over
/// `#[cfg(test)] mod <name>;` declarations) so the rules only see
/// production code.  `#[cfg(test)]` on a single item (fn/impl) is left
/// in — only whole test *modules* are stripped, which matches how this
/// repo organizes its tests.
pub fn strip_cfg_test(toks: &[Tok]) -> Vec<Tok> {
    let mut out: Vec<Tok> = Vec::with_capacity(toks.len());
    let mut i = 0usize;
    while i < toks.len() {
        if tx(toks, i) == "#"
            && tx(toks, i + 1) == "["
            && tx(toks, i + 2) == "cfg"
            && tx(toks, i + 3) == "("
            && tx(toks, i + 4) == "test"
            && tx(toks, i + 5) == ")"
            && tx(toks, i + 6) == "]"
            && tx(toks, i + 7) == "mod"
        {
            let mut j = i + 8;
            while j < toks.len() && tx(toks, j) != "{" && tx(toks, j) != ";" {
                j += 1;
            }
            if j >= toks.len() {
                break;
            }
            if tx(toks, j) == ";" {
                i = j + 1;
                continue;
            }
            let mut depth = 0i64;
            while j < toks.len() {
                match tx(toks, j) {
                    "{" => depth += 1,
                    "}" => {
                        depth -= 1;
                        if depth == 0 {
                            j += 1;
                            break;
                        }
                    }
                    _ => {}
                }
                j += 1;
            }
            i = j;
            continue;
        }
        out.push(toks[i].clone());
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).toks.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn basic_tokens() {
        assert_eq!(texts("let x = a.unwrap();"), vec!["let", "x", "=", "a", ".", "unwrap", "(", ")", ";"]);
    }

    #[test]
    fn ranges_survive() {
        assert_eq!(texts("0..n"), vec!["0", ".", ".", "n"]);
        assert_eq!(texts("1.5 + 2"), vec!["1.5", "+", "2"]);
    }

    #[test]
    fn strings_and_raw_strings() {
        let l = lex(r###"let a = "x\"y"; let b = r#"{"stats":true}"#;"###);
        let strs: Vec<&str> = l.toks.iter().filter(|t| t.kind == Kind::Str).map(|t| t.text.as_str()).collect();
        assert_eq!(strs, vec!["x\\\"y", r#"{"stats":true}"#]);
    }

    #[test]
    fn lifetimes_vs_chars() {
        let l = lex("fn f<'a>(x: &'a str) { let c = 'x'; let d = '\\n'; }");
        assert!(l.toks.iter().all(|t| t.text != "a" || t.kind == Kind::Ident));
        // no stray quote punctuation survives
        assert!(l.toks.iter().all(|t| t.text != "'"));
    }

    #[test]
    fn comments_collected_with_lines() {
        let l = lex("// one\nlet x = 1; // two\n/* three\nfour */\nlet y = 2;");
        assert_eq!(l.comments.len(), 3);
        assert_eq!(l.comments[0].line, 1);
        assert_eq!(l.comments[1].line, 2);
        assert_eq!(l.comments[2].line, 3);
        assert!(l.comments[2].text.contains("four"));
    }

    #[test]
    fn strip_test_mods() {
        let src = "fn prod() {}\n#[cfg(test)]\nmod tests { fn t() { x.unwrap(); } }\nfn prod2() {}";
        let l = lex(src);
        let s = strip_cfg_test(&l.toks);
        let texts: Vec<&str> = s.iter().map(|t| t.text.as_str()).collect();
        assert!(texts.contains(&"prod"));
        assert!(texts.contains(&"prod2"));
        assert!(!texts.contains(&"unwrap"));
    }

    #[test]
    fn shebang_skipped_but_inner_attr_lexes() {
        // a shebang line is not tokens and must not desync line numbers
        let l = lex("#!/usr/bin/env run-cargo-script\nfn main() {}");
        let texts: Vec<&str> = l.toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(texts, vec!["fn", "main", "(", ")", "{", "}"]);
        assert_eq!(l.toks[0].line, 2);
        // `#![...]` is an inner attribute, not a shebang
        let l = lex("#![allow(dead_code)]\nfn main() {}");
        let texts: Vec<&str> = l.toks.iter().map(|t| t.text.as_str()).collect();
        assert!(texts.starts_with(&["#", "!", "[", "allow"]), "{texts:?}");
    }

    #[test]
    fn float_exponents_stay_one_token() {
        assert_eq!(texts("1e-5 + 2.5E+10 - 3e7"), vec!["1e-5", "+", "2.5E+10", "-", "3e7"]);
        // hex `e` is a digit, not an exponent: `-` stays an operator
        assert_eq!(texts("0x1e - 5"), vec!["0x1e", "-", "5"]);
        // `1e - x` (no digit after sign) is not an exponent
        assert_eq!(texts("1e - x"), vec!["1e", "-", "x"]);
    }

    #[test]
    fn nested_block_comments_deep() {
        let l = lex("/* a /* b /* c */ d */ e */ fn f() {}");
        let texts: Vec<&str> = l.toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(texts, vec!["fn", "f", "(", ")", "{", "}"]);
        assert_eq!(l.comments.len(), 1);
        assert!(l.comments[0].text.contains("c */ d"));
    }

    #[test]
    fn raw_strings_with_comment_markers_inside() {
        let src = "let a = r#\"// not a comment /* nor this */\"#; let b = 1;";
        let l = lex(src);
        assert!(l.comments.is_empty());
        let strs: Vec<&str> =
            l.toks.iter().filter(|t| t.kind == Kind::Str).map(|t| t.text.as_str()).collect();
        assert_eq!(strs, vec!["// not a comment /* nor this */"]);
        assert!(l.toks.iter().any(|t| t.text == "b"));
    }

    #[test]
    fn lifetime_then_char_sequences() {
        // `<'a>` lifetime, `'x'` char, `b'x'` byte char, `'\\'` escaped
        let l = lex("fn f<'a>() { let c = 'x'; let d = b'y'; let e = '\\\\'; }");
        assert!(l.toks.iter().all(|t| t.text != "'"));
        assert!(l.toks.iter().any(|t| t.text == "e"));
    }

    #[test]
    fn strip_test_mod_decl() {
        let src = "#[cfg(test)]\nmod props;\nfn prod() {}";
        let l = lex(src);
        let s = strip_cfg_test(&l.toks);
        assert!(s.iter().any(|t| t.text == "prod"));
    }
}
