//! Best-effort call graph over the [`ItemGraph`], plus the reachability
//! and bottom-up summary helpers the interprocedural rules share.
//!
//! Call sites are the lexical patterns `name(` and `.name(`; a site is
//! linked to every in-crate fn that plausibly resolves to it:
//!
//! * `.name(` method calls link to fns named `name` that sit inside an
//!   `impl` (preferring them over free fns when both exist);
//! * `Qual::name(` qualified calls link to fns whose impl target is
//!   `Qual` when any exist, else to every fn named `name`;
//! * bare `name(` calls prefer same-file fns, else every fn named
//!   `name`.
//!
//! This over-approximates (no receiver types, no trait dispatch) and
//! under-approximates (closures and fn-pointers passed by name are not
//! edges).  Rules that rely on it say which direction they err.

use std::collections::{HashMap, HashSet, VecDeque};

use crate::lexer::Kind;
use crate::resolve::{tx, ItemGraph};
use crate::SourceFile;

#[derive(Clone, Copy, Debug)]
pub struct CallSite {
    /// Callee index into `ItemGraph::fns`.
    pub callee: usize,
    /// Token index of the callee name in the caller's file.
    pub tok: usize,
    pub line: usize,
}

pub struct CallGraph {
    /// Outgoing call sites per fn (indexed like `ItemGraph::fns`).
    pub calls: Vec<Vec<CallSite>>,
}

impl CallGraph {
    pub fn build(files: &[SourceFile], items: &ItemGraph) -> CallGraph {
        let mut calls: Vec<Vec<CallSite>> = vec![Vec::new(); items.fns.len()];
        for (fi, f) in items.fns.iter().enumerate() {
            let Some((open, close)) = f.body else { continue };
            let t = &files[f.file].toks;
            for i in (open + 1)..close {
                if t[i].kind != Kind::Ident || tx(t, i + 1) != "(" {
                    continue;
                }
                if tx(t, i.wrapping_sub(1)) == "fn" {
                    continue; // nested fn definition header
                }
                let Some(cands) = items.by_name.get(&t[i].text) else { continue };
                let resolved: Vec<usize> = if tx(t, i.wrapping_sub(1)) == "." {
                    // method call: prefer impl fns
                    let impls: Vec<usize> = cands
                        .iter()
                        .copied()
                        .filter(|&c| items.fns[c].impl_target.is_some())
                        .collect();
                    if impls.is_empty() { cands.clone() } else { impls }
                } else if tx(t, i.wrapping_sub(1)) == ":"
                    && tx(t, i.wrapping_sub(2)) == ":"
                    && t.get(i.wrapping_sub(3)).map(|k| k.kind == Kind::Ident).unwrap_or(false)
                {
                    // qualified call: prefer fns whose impl target matches
                    let q = tx(t, i.wrapping_sub(3));
                    let m: Vec<usize> = cands
                        .iter()
                        .copied()
                        .filter(|&c| items.fns[c].impl_target.as_deref() == Some(q))
                        .collect();
                    if m.is_empty() { cands.clone() } else { m }
                } else {
                    // bare call: prefer same-file fns
                    let same: Vec<usize> =
                        cands.iter().copied().filter(|&c| items.fns[c].file == f.file).collect();
                    if same.is_empty() { cands.clone() } else { same }
                };
                for callee in resolved {
                    calls[fi].push(CallSite { callee, tok: i, line: t[i].line });
                }
            }
        }
        CallGraph { calls }
    }

    /// Shortest-path next-hop table toward any fn in `targets`: for every
    /// fn that can reach a target through call edges, the first call site
    /// on a shortest path.  Targets themselves map to `None`.
    pub fn next_hops(&self, targets: &HashSet<usize>) -> HashMap<usize, Option<CallSite>> {
        // reverse adjacency: callee -> (caller, site)
        let mut rev: HashMap<usize, Vec<(usize, CallSite)>> = HashMap::new();
        for (caller, sites) in self.calls.iter().enumerate() {
            for &s in sites {
                rev.entry(s.callee).or_default().push((caller, s));
            }
        }
        let mut hops: HashMap<usize, Option<CallSite>> = HashMap::new();
        let mut q: VecDeque<usize> = VecDeque::new();
        for &t in targets {
            hops.insert(t, None);
            q.push_back(t);
        }
        while let Some(v) = q.pop_front() {
            if let Some(callers) = rev.get(&v) {
                for &(caller, site) in callers {
                    hops.entry(caller).or_insert_with(|| {
                        q.push_back(caller);
                        Some(site)
                    });
                }
            }
        }
        hops
    }

    /// The chain of call sites from `from` toward a target per the
    /// next-hop table (empty when `from` is itself a target).
    pub fn chain(&self, hops: &HashMap<usize, Option<CallSite>>, from: usize) -> Vec<CallSite> {
        let mut out = Vec::new();
        let mut cur = from;
        let mut budget = 64usize;
        while budget > 0 {
            budget -= 1;
            match hops.get(&cur) {
                Some(Some(site)) => {
                    out.push(*site);
                    cur = site.callee;
                }
                _ => break,
            }
        }
        out
    }

    /// Forward reachability: every fn reachable from `seeds` through call
    /// edges (seeds included).
    pub fn reachable_from(&self, seeds: &HashSet<usize>) -> HashSet<usize> {
        let mut seen: HashSet<usize> = seeds.clone();
        let mut q: VecDeque<usize> = seeds.iter().copied().collect();
        while let Some(v) = q.pop_front() {
            for s in &self.calls[v] {
                if seen.insert(s.callee) {
                    q.push_back(s.callee);
                }
            }
        }
        seen
    }

    /// Bottom-up set propagation to a fixpoint: each fn's set becomes its
    /// local set unioned with every callee's (handles recursion by
    /// iterating until stable).
    pub fn propagate_sets(&self, local: &[HashSet<String>]) -> Vec<HashSet<String>> {
        let mut all: Vec<HashSet<String>> = local.to_vec();
        loop {
            let mut changed = false;
            for f in 0..all.len() {
                for si in 0..self.calls[f].len() {
                    let callee = self.calls[f][si].callee;
                    if callee == f {
                        continue;
                    }
                    let add: Vec<String> =
                        all[callee].difference(&all[f]).cloned().collect();
                    if !add.is_empty() {
                        all[f].extend(add);
                        changed = true;
                    }
                }
            }
            if !changed {
                return all;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::source_from;

    fn build(srcs: &[(&str, &str)]) -> (Vec<SourceFile>, ItemGraph, CallGraph) {
        let mut files = Vec::new();
        for (p, s) in srcs {
            let (f, v) = source_from(p, s);
            assert!(v.is_empty(), "{v:?}");
            files.push(f);
        }
        let items = ItemGraph::build(&files);
        let cg = CallGraph::build(&files, &items);
        (files, items, cg)
    }

    fn idx(items: &ItemGraph, name: &str) -> usize {
        items.by_name[name][0]
    }

    #[test]
    fn cross_file_edges_and_chains() {
        let (_, items, cg) = build(&[
            ("rust/src/a.rs", "pub fn top() { mid(1); }\nfn mid(x: u32) { bottom(); }"),
            ("rust/src/b.rs", "pub fn bottom() { }"),
        ]);
        let top = idx(&items, "top");
        let bottom = idx(&items, "bottom");
        let hops = cg.next_hops(&[bottom].into_iter().collect());
        assert!(hops.contains_key(&top));
        let chain = cg.chain(&hops, top);
        let names: Vec<&str> =
            chain.iter().map(|s| items.fns[s.callee].name.as_str()).collect();
        assert_eq!(names, vec!["mid", "bottom"]);
    }

    #[test]
    fn qualified_calls_prefer_impl_target() {
        let (_, items, cg) = build(&[(
            "rust/src/a.rs",
            "struct A; struct B;\n\
             impl A { pub fn go() {} }\n\
             impl B { pub fn go() {} }\n\
             fn f() { A::go(); }",
        )]);
        let f = idx(&items, "f");
        assert_eq!(cg.calls[f].len(), 1);
        let callee = &items.fns[cg.calls[f][0].callee];
        assert_eq!(callee.impl_target.as_deref(), Some("A"));
    }

    #[test]
    fn recursion_reaches_fixpoint() {
        let (_, items, cg) = build(&[(
            "rust/src/a.rs",
            "fn a() { b(); }\nfn b() { a(); leaf(); }\nfn leaf() {}",
        )]);
        let mut local: Vec<HashSet<String>> = vec![HashSet::new(); items.fns.len()];
        local[idx(&items, "leaf")].insert("L".to_string());
        let all = cg.propagate_sets(&local);
        assert!(all[idx(&items, "a")].contains("L"));
        assert!(all[idx(&items, "b")].contains("L"));
    }

    #[test]
    fn forward_reachability() {
        let (_, items, cg) = build(&[(
            "rust/src/a.rs",
            "pub fn root() { helper(); }\nfn helper() { deep(); }\nfn deep() {}\nfn island() {}",
        )]);
        let r = cg.reachable_from(&[idx(&items, "root")].into_iter().collect());
        assert!(r.contains(&idx(&items, "deep")));
        assert!(!r.contains(&idx(&items, "island")));
    }
}
