//! `hass-analyze` — the repo's own lint pass over `rust/src`.
//!
//! The HASS serving stack rests on invariants the compiler cannot see
//! (solo == fused token-for-token, `(id,stamp)` page identity, COW
//! isolation, mask visibility).  This crate walks the production sources
//! with a small lexer and enforces the conventions that keep those
//! invariants checkable:
//!
//! * `no-unwrap` — no `.unwrap()` / `.expect(...)` / indexing into a call
//!   result inside the fused-path modules (`scheduler`, `engine/sessions`,
//!   `kvcache`) unless annotated.
//! * `send-hygiene` — no `Rc`/`Cell`/`RefCell` fields on types reachable
//!   from an `Arc<...>`/channel boundary, and none named inside a
//!   `spawn(...)` closure (pre-flight gate for the Arc page-pool
//!   migration).
//! * `stamp-discipline` — every storage-writing `pub fn` on
//!   `KvCache`/`Page` carries the `#[hass::mutates_storage]` doc marker
//!   and bumps `stamp` on its write path, and vice versa.
//! * `wire-drift` — every JSON key the client/stats paths *read* must be
//!   *emitted* somewhere by the server/scheduler.
//! * `panic-isolation` — every `spawn(...)` in `scheduler`/`server` wraps
//!   its body in `catch_unwind`.
//! * `unsafe-comment` — every `unsafe` block carries a `// SAFETY:`
//!   comment within the preceding 3 lines.
//!
//! Violations are silenced site-by-site with
//! `// hass-lint: allow(<rule>[, <rule>...]) — <justification>`; the
//! justification is mandatory (see README.md).  Annotations cover their
//! own line and the next one.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

pub mod lexer;
pub mod rules;

use lexer::{Comment, Lexed, Tok};

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub rule: String,
    pub msg: String,
}

pub struct SourceFile {
    /// Path with `/` separators (rule matchers are written against it).
    pub path: String,
    /// Test-stripped token stream (no `#[cfg(test)] mod` bodies).
    pub toks: Vec<Tok>,
    /// All comments, with line numbers (tests included — annotations and
    /// SAFETY comments live here).
    pub comments: Vec<Comment>,
    /// line -> rules allowed on that line by `hass-lint: allow(...)`.
    pub allows: HashMap<usize, Vec<String>>,
}

impl SourceFile {
    pub fn allowed(&self, rule: &str, line: usize) -> bool {
        self.allows
            .get(&line)
            .map(|rs| rs.iter().any(|r| r == rule || r == "all"))
            .unwrap_or(false)
    }
}

/// Build a [`SourceFile`] from in-memory source (used by the rule tests
/// and by [`run_sources`]).  Malformed `hass-lint:` annotations are
/// reported through the returned violations.
pub fn source_from(path: &str, src: &str) -> (SourceFile, Vec<Violation>) {
    let Lexed { toks, comments } = lexer::lex(src);
    let stripped = lexer::strip_cfg_test(&toks);
    let (allows, viols) = parse_allow_comments(path, &comments);
    (SourceFile { path: path.to_string(), toks: stripped, comments, allows }, viols)
}

/// Parse every `hass-lint: allow(<rules>) — <justification>` annotation.
/// The annotation silences the listed rules on its own line and the next;
/// a missing rule list or missing justification is itself a violation
/// (`allow-syntax`) — an allow that doesn't say *why* is a convention
/// hole, not an exemption.
fn parse_allow_comments(
    path: &str,
    comments: &[Comment],
) -> (HashMap<usize, Vec<String>>, Vec<Violation>) {
    let mut map: HashMap<usize, Vec<String>> = HashMap::new();
    let mut viols: Vec<Violation> = Vec::new();
    let bad = |line: usize| Violation {
        file: path.to_string(),
        line,
        rule: "allow-syntax".to_string(),
        msg: "malformed `hass-lint:` annotation — expected \
              `hass-lint: allow(<rule>[, <rule>]) — <justification>`"
            .to_string(),
    };
    for c in comments {
        let Some(pos) = c.text.find("hass-lint:") else { continue };
        let rest = c.text[pos + "hass-lint:".len()..].trim_start();
        let Some(rest) = rest.strip_prefix("allow") else {
            viols.push(bad(c.line));
            continue;
        };
        let rest = rest.trim_start();
        let Some(rest) = rest.strip_prefix('(') else {
            viols.push(bad(c.line));
            continue;
        };
        let Some(close) = rest.find(')') else {
            viols.push(bad(c.line));
            continue;
        };
        let rules: Vec<String> = rest[..close]
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        let substantive = rest[close + 1..]
            .chars()
            .filter(|ch| ch.is_alphanumeric())
            .count();
        if rules.is_empty() || substantive < 3 {
            viols.push(bad(c.line));
            continue;
        }
        for l in [c.line, c.line + 1] {
            map.entry(l).or_default().extend(rules.iter().cloned());
        }
    }
    (map, viols)
}

/// Recursively collect `.rs` files under `root` (skipping `vendor/` and
/// build output), sorted for deterministic reports.
fn collect_rs(root: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(root) else { return };
    for e in entries.flatten() {
        let p = e.path();
        if p.is_dir() {
            let name = e.file_name();
            let name = name.to_string_lossy();
            if name == "vendor" || name == "target" || name.starts_with('.') {
                continue;
            }
            collect_rs(&p, out);
        } else if p.extension().map(|x| x == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
}

/// Analyze in-memory sources: `(path, source)` pairs.  Returns all
/// violations sorted by (file, line).
pub fn run_sources(sources: &[(&str, &str)]) -> Vec<Violation> {
    let mut files: Vec<SourceFile> = Vec::with_capacity(sources.len());
    let mut viols: Vec<Violation> = Vec::new();
    for (path, src) in sources {
        let (f, v) = source_from(path, src);
        viols.extend(v);
        files.push(f);
    }
    viols.extend(rules::check_crate(&files));
    viols.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    viols
}

/// Analyze the given roots (files or directories).  Returns the
/// violations plus the number of files scanned.
pub fn run(paths: &[String]) -> std::io::Result<(Vec<Violation>, usize)> {
    let mut list: Vec<PathBuf> = Vec::new();
    for p in paths {
        let pb = PathBuf::from(p);
        if pb.is_dir() {
            collect_rs(&pb, &mut list);
        } else {
            list.push(pb);
        }
    }
    list.sort();
    list.dedup();
    let mut files: Vec<SourceFile> = Vec::with_capacity(list.len());
    let mut viols: Vec<Violation> = Vec::new();
    for pb in &list {
        let src = std::fs::read_to_string(pb)?;
        let path = pb.to_string_lossy().replace('\\', "/");
        let (f, v) = source_from(&path, &src);
        viols.extend(v);
        files.push(f);
    }
    let n = files.len();
    viols.extend(rules::check_crate(&files));
    viols.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok((viols, n))
}

/// CLI driver: print `path:line: [rule] msg` lines and return the exit
/// code (0 = clean, 1 = violations, 2 = I/O error).
pub fn run_cli(paths: &[String]) -> i32 {
    let default = vec!["rust/src".to_string()];
    let paths = if paths.is_empty() { &default } else { paths };
    match run(paths) {
        Ok((viols, n)) => {
            for v in &viols {
                println!("{}:{}: [{}] {}", v.file, v.line, v.rule, v.msg);
            }
            println!("hass-analyze: {} file(s) scanned, {} violation(s)", n, viols.len());
            if viols.is_empty() {
                0
            } else {
                1
            }
        }
        Err(e) => {
            eprintln!("hass-analyze: {e}");
            2
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allow_annotation_grammar() {
        let (f, v) = source_from(
            "x.rs",
            "// hass-lint: allow(no-unwrap) — page was ensured two lines up\nlet x = 1;",
        );
        assert!(v.is_empty());
        assert!(f.allowed("no-unwrap", 1));
        assert!(f.allowed("no-unwrap", 2));
        assert!(!f.allowed("no-unwrap", 3));
        assert!(!f.allowed("send-hygiene", 1));
    }

    #[test]
    fn allow_without_justification_fires() {
        let (_, v) = source_from("x.rs", "// hass-lint: allow(no-unwrap)\nlet x = 1;");
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "allow-syntax");
    }

    #[test]
    fn allow_multiple_rules() {
        let (f, v) = source_from(
            "x.rs",
            "// hass-lint: allow(no-unwrap, send-hygiene) — test fixture plumbing\nlet x = 1;",
        );
        assert!(v.is_empty());
        assert!(f.allowed("no-unwrap", 2));
        assert!(f.allowed("send-hygiene", 2));
    }

    #[test]
    fn malformed_allow_fires() {
        let (_, v) = source_from("x.rs", "// hass-lint: alow(no-unwrap) — typo\n");
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "allow-syntax");
    }
}
