//! `hass-analyze` — the repo's own whole-crate lint pass over `rust/src`.
//!
//! The HASS serving stack rests on invariants the compiler cannot see
//! (solo == fused token-for-token, `(id,stamp)` page identity, COW
//! isolation, mask visibility).  This crate parses the production
//! sources into an item graph (`resolve`) and a best-effort call graph
//! (`callgraph`) and enforces the conventions that keep those invariants
//! checkable:
//!
//! * `no-unwrap` — no `.unwrap()` / `.expect(...)` / indexing into a call
//!   result inside the fused-path modules (`scheduler`, `engine/sessions`,
//!   `kvcache`) unless annotated.
//! * `send-hygiene` — no `Rc`/`Cell`/`RefCell` fields (alias-aware) on
//!   types reachable from an `Arc<...>`/channel boundary.
//! * `lock-order` — no potential acquisition cycles between
//!   `util::lockorder` classes on any call path (static complement of
//!   the `HASS_CHECK=1` runtime inversion detector).
//! * `thread-escape` — no binding or call result whose type reaches
//!   `Rc`/`Cell` may flow into a spawn capture, channel send, or
//!   `Arc::new` span.
//! * `stamp-discipline` — any fn that can reach `page_mut`/`next_stamp`
//!   through any call chain carries the `#[hass::mutates_storage]` doc
//!   marker or is a private helper of a marked fn, and vice versa.
//! * `wire-drift` / `wire-dead` — every JSON key read must be emitted
//!   somewhere, and every emitted key must have a reader.
//! * `panic-isolation` — every `spawn(...)` in `scheduler`/`server` wraps
//!   its body in `catch_unwind`.
//! * `unsafe-comment` — every `unsafe` block carries a `// SAFETY:`
//!   comment within the preceding 3 lines.
//!
//! Violations are silenced site-by-site with
//! `// hass-lint: allow(<rule>[, <rule>...]) — <justification>`; the
//! justification is mandatory (see README.md).  Annotations cover their
//! own line and the next one.  Whole findings can instead be
//! grandfathered in a reviewed baseline (`--baseline`, see `report`).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

pub mod callgraph;
pub mod lexer;
pub mod report;
pub mod resolve;
pub mod rules;

use lexer::{Comment, Lexed, Tok};
use report::{fingerprint, Baseline, Format};

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub rule: String,
    /// "error" or "warning" (both gate CI unless baselined; the level
    /// only affects how GitHub annotations render).
    pub severity: String,
    pub msg: String,
    /// Witness chain: how the rule got here (call frames, field chains,
    /// binding sites), outermost first.
    pub witness: Vec<String>,
}

pub struct SourceFile {
    /// Path with `/` separators (rule matchers are written against it).
    pub path: String,
    /// Test-stripped token stream (no `#[cfg(test)] mod` bodies).
    pub toks: Vec<Tok>,
    /// Full token stream, tests included (wire-dead counts test readers
    /// as consumers).
    pub toks_full: Vec<Tok>,
    /// All comments, with line numbers (tests included — annotations and
    /// SAFETY comments live here).
    pub comments: Vec<Comment>,
    /// line -> rules allowed on that line by `hass-lint: allow(...)`.
    pub allows: HashMap<usize, Vec<String>>,
}

impl SourceFile {
    pub fn allowed(&self, rule: &str, line: usize) -> bool {
        self.allows
            .get(&line)
            .map(|rs| rs.iter().any(|r| r == rule || r == "all"))
            .unwrap_or(false)
    }
}

/// Build a [`SourceFile`] from in-memory source (used by the rule tests
/// and by [`run_sources`]).  Malformed `hass-lint:` annotations are
/// reported through the returned violations.
pub fn source_from(path: &str, src: &str) -> (SourceFile, Vec<Violation>) {
    let Lexed { toks, comments } = lexer::lex(src);
    let stripped = lexer::strip_cfg_test(&toks);
    let (allows, viols) = parse_allow_comments(path, &comments);
    (
        SourceFile {
            path: path.to_string(),
            toks: stripped,
            toks_full: toks,
            comments,
            allows,
        },
        viols,
    )
}

/// Parse every `hass-lint: allow(<rules>) — <justification>` annotation.
/// The annotation silences the listed rules on its own line and the next;
/// a missing rule list or missing justification is itself a violation
/// (`allow-syntax`) — an allow that doesn't say *why* is a convention
/// hole, not an exemption.
fn parse_allow_comments(
    path: &str,
    comments: &[Comment],
) -> (HashMap<usize, Vec<String>>, Vec<Violation>) {
    let mut map: HashMap<usize, Vec<String>> = HashMap::new();
    let mut viols: Vec<Violation> = Vec::new();
    let bad = |line: usize| Violation {
        file: path.to_string(),
        line,
        rule: "allow-syntax".to_string(),
        severity: "error".to_string(),
        msg: "malformed `hass-lint:` annotation — expected \
              `hass-lint: allow(<rule>[, <rule>]) — <justification>`"
            .to_string(),
        witness: Vec::new(),
    };
    for c in comments {
        let Some(pos) = c.text.find("hass-lint:") else { continue };
        let rest = c.text[pos + "hass-lint:".len()..].trim_start();
        let Some(rest) = rest.strip_prefix("allow") else {
            viols.push(bad(c.line));
            continue;
        };
        let rest = rest.trim_start();
        let Some(rest) = rest.strip_prefix('(') else {
            viols.push(bad(c.line));
            continue;
        };
        let Some(close) = rest.find(')') else {
            viols.push(bad(c.line));
            continue;
        };
        let rules: Vec<String> = rest[..close]
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        let substantive = rest[close + 1..]
            .chars()
            .filter(|ch| ch.is_alphanumeric())
            .count();
        if rules.is_empty() || substantive < 3 {
            viols.push(bad(c.line));
            continue;
        }
        for l in [c.line, c.line + 1] {
            map.entry(l).or_default().extend(rules.iter().cloned());
        }
    }
    (map, viols)
}

/// Recursively collect `.rs` files under `root` (skipping `vendor/` and
/// build output), sorted for deterministic reports.
fn collect_rs(root: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(root) else { return };
    for e in entries.flatten() {
        let p = e.path();
        if p.is_dir() {
            let name = e.file_name();
            let name = name.to_string_lossy();
            if name == "vendor" || name == "target" || name.starts_with('.') {
                continue;
            }
            collect_rs(&p, out);
        } else if p.extension().map(|x| x == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
}

/// Analyze in-memory sources: `(path, source)` pairs.  Returns all
/// violations sorted by (file, line).
pub fn run_sources(sources: &[(&str, &str)]) -> Vec<Violation> {
    let mut files: Vec<SourceFile> = Vec::with_capacity(sources.len());
    let mut viols: Vec<Violation> = Vec::new();
    for (path, src) in sources {
        let (f, v) = source_from(path, src);
        viols.extend(v);
        files.push(f);
    }
    viols.extend(rules::check_crate(&files));
    viols.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    viols
}

/// Analyze the given roots (files or directories).  Returns the
/// violations plus the number of files scanned.
pub fn run(paths: &[String]) -> std::io::Result<(Vec<Violation>, usize)> {
    let mut list: Vec<PathBuf> = Vec::new();
    for p in paths {
        let pb = PathBuf::from(p);
        if pb.is_dir() {
            collect_rs(&pb, &mut list);
        } else {
            list.push(pb);
        }
    }
    list.sort();
    list.dedup();
    let mut files: Vec<SourceFile> = Vec::with_capacity(list.len());
    let mut viols: Vec<Violation> = Vec::new();
    for pb in &list {
        let src = std::fs::read_to_string(pb)?;
        let path = pb.to_string_lossy().replace('\\', "/");
        let (f, v) = source_from(&path, &src);
        viols.extend(v);
        files.push(f);
    }
    let n = files.len();
    viols.extend(rules::check_crate(&files));
    viols.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok((viols, n))
}

/// CLI driver.  Accepts the full argument vector:
///
/// ```text
/// hass-analyze [--format text|json|github] [--baseline <file>]
///              [--update-baseline] [paths...]
/// ```
///
/// Exit codes: 0 = clean (or baseline updated), 1 = new findings,
/// 2 = I/O error or bad arguments.  With `--baseline`, findings whose
/// fingerprint is listed are suppressed and only *new* findings gate.
pub fn run_cli(args: &[String]) -> i32 {
    let mut format = Format::Text;
    let mut baseline_path: Option<String> = None;
    let mut update_baseline = false;
    let mut paths: Vec<String> = Vec::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        let (flag, inline) = match a.split_once('=') {
            Some((f, v)) if f.starts_with("--") => (f, Some(v.to_string())),
            _ => (a.as_str(), None),
        };
        match flag {
            "--format" => {
                let Some(v) = inline.or_else(|| it.next().cloned()) else {
                    eprintln!("hass-analyze: --format needs a value (text|json|github)");
                    return 2;
                };
                let Some(f) = Format::parse(&v) else {
                    eprintln!("hass-analyze: unknown format `{v}` (expected text|json|github)");
                    return 2;
                };
                format = f;
            }
            "--baseline" => {
                let Some(v) = inline.or_else(|| it.next().cloned()) else {
                    eprintln!("hass-analyze: --baseline needs a file path");
                    return 2;
                };
                baseline_path = Some(v);
            }
            "--update-baseline" => update_baseline = true,
            s if s.starts_with("--") => {
                eprintln!("hass-analyze: unknown flag `{s}`");
                return 2;
            }
            _ => paths.push(a.clone()),
        }
    }
    if update_baseline && baseline_path.is_none() {
        eprintln!("hass-analyze: --update-baseline requires --baseline <file>");
        return 2;
    }
    let default = vec!["rust/src".to_string()];
    let paths = if paths.is_empty() { default } else { paths };
    let (viols, n) = match run(&paths) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("hass-analyze: {e}");
            return 2;
        }
    };
    let baseline = match &baseline_path {
        Some(p) => match std::fs::read_to_string(p) {
            Ok(s) => Baseline::parse(&s),
            // A missing file is fine when we're about to create it.
            Err(_) if update_baseline => Baseline::default(),
            Err(e) => {
                eprintln!("hass-analyze: cannot read baseline `{p}`: {e}");
                return 2;
            }
        },
        None => Baseline::default(),
    };
    if update_baseline {
        if let Some(p) = &baseline_path {
            let text = baseline.render_updated(&viols);
            if let Err(e) = std::fs::write(p, text) {
                eprintln!("hass-analyze: cannot write baseline `{p}`: {e}");
                return 2;
            }
            println!(
                "hass-analyze: baseline `{p}` updated to cover {} finding(s)",
                viols.len()
            );
        }
        return 0;
    }
    let mut fresh: Vec<Violation> = Vec::new();
    let mut suppressed = 0usize;
    for v in viols {
        if baseline.contains(&fingerprint(&v)) {
            suppressed += 1;
        } else {
            fresh.push(v);
        }
    }
    print!("{}", report::render(&fresh, format, n, suppressed));
    if fresh.is_empty() {
        0
    } else {
        1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allow_annotation_grammar() {
        let (f, v) = source_from(
            "x.rs",
            "// hass-lint: allow(no-unwrap) — page was ensured two lines up\nlet x = 1;",
        );
        assert!(v.is_empty());
        assert!(f.allowed("no-unwrap", 1));
        assert!(f.allowed("no-unwrap", 2));
        assert!(!f.allowed("no-unwrap", 3));
        assert!(!f.allowed("send-hygiene", 1));
    }

    #[test]
    fn allow_without_justification_fires() {
        let (_, v) = source_from("x.rs", "// hass-lint: allow(no-unwrap)\nlet x = 1;");
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "allow-syntax");
    }

    #[test]
    fn allow_multiple_rules() {
        let (f, v) = source_from(
            "x.rs",
            "// hass-lint: allow(no-unwrap, send-hygiene) — test fixture plumbing\nlet x = 1;",
        );
        assert!(v.is_empty());
        assert!(f.allowed("no-unwrap", 2));
        assert!(f.allowed("send-hygiene", 2));
    }

    #[test]
    fn malformed_allow_fires() {
        let (_, v) = source_from("x.rs", "// hass-lint: alow(no-unwrap) — typo\n");
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "allow-syntax");
    }
}
