//! Integration fixtures for the whole-crate rules (R7 lock-order,
//! R8 thread-escape, R9 stamp-discipline) plus the baseline workflow.
//!
//! Unlike the unit tests in `src/rules.rs` (single-file, rule-at-a-time)
//! these fixtures cross file boundaries the way the production tree
//! does — the call graph has to resolve callees in *other* files for the
//! witness chains to come out right — and they assert the witness
//! chains EXACTLY, line numbers included.  If a refactor changes how
//! frames are rendered, these tests are the contract that breaks.

use hass_analyze::report::{fingerprint, Baseline};
use hass_analyze::run_sources;

// ---------------------------------------------------------------------
// R7 lock-order
// ---------------------------------------------------------------------

/// Two files acquire WORKER_QUEUE and STATS in opposite orders, each
/// through a one-call indirection.  One cycle, reported once, anchored
/// at the lexicographically smallest class (STATS), with a full
/// acquire -> call -> acquire witness for BOTH edges.
#[test]
fn r7_cross_file_inversion_exact_witness() {
    let sched = "fn push_job() {\n\
                 \x20   let _q = trace(WORKER_QUEUE);\n\
                 \x20   bump_stats();\n\
                 }\n\
                 fn bump_stats() {\n\
                 \x20   let _s = trace(STATS);\n\
                 }\n";
    let drain = "fn drain() {\n\
                 \x20   let _s = trace(STATS);\n\
                 \x20   requeue();\n\
                 }\n\
                 fn requeue() {\n\
                 \x20   let _q = trace(WORKER_QUEUE);\n\
                 }\n";
    let v = run_sources(&[
        ("rust/src/scheduler/mod.rs", sched),
        ("rust/src/scheduler/drain.rs", drain),
    ]);
    assert_eq!(v.len(), 1, "{v:?}");
    assert_eq!(v[0].rule, "lock-order");
    // anchored at the STATS -> WORKER_QUEUE edge (drain.rs, line 2)
    assert_eq!(v[0].file, "rust/src/scheduler/drain.rs");
    assert_eq!(v[0].line, 2);
    assert!(
        v[0].msg.contains("potential lock-order cycle: STATS -> WORKER_QUEUE -> STATS"),
        "{}",
        v[0].msg
    );
    assert_eq!(
        v[0].witness,
        vec![
            "rust/src/scheduler/drain.rs:2: drain acquires STATS".to_string(),
            "rust/src/scheduler/drain.rs:3: drain -> requeue".to_string(),
            "rust/src/scheduler/drain.rs:6: requeue acquires WORKER_QUEUE".to_string(),
            "rust/src/scheduler/mod.rs:2: push_job acquires WORKER_QUEUE".to_string(),
            "rust/src/scheduler/mod.rs:3: push_job -> bump_stats".to_string(),
            "rust/src/scheduler/mod.rs:6: bump_stats acquires STATS".to_string(),
        ]
    );
}

/// Same two classes, same indirection depth, but every path acquires
/// WORKER_QUEUE before STATS: no cycle, no finding.
#[test]
fn r7_cross_file_consistent_order_is_clean() {
    let a = "fn push_job() { let _q = trace(WORKER_QUEUE); bump_stats(); }\n\
             fn bump_stats() { let _s = trace(STATS); }\n";
    let b = "fn drain() { let _q = trace(WORKER_QUEUE); flush_stats(); }\n\
             fn flush_stats() { let _s = trace(STATS); }\n";
    let v = run_sources(&[
        ("rust/src/scheduler/mod.rs", a),
        ("rust/src/scheduler/drain.rs", b),
    ]);
    assert!(v.is_empty(), "{v:?}");
}

// ---------------------------------------------------------------------
// R8 thread-escape
// ---------------------------------------------------------------------

/// A helper in another file returns a `Handle` that embeds an `Rc`; the
/// caller binds it and moves it into a `spawn`.  The witness walks the
/// whole flow: capture site -> binding -> returning call -> type chain
/// down to the non-Send core.
#[test]
fn r8_helper_returned_handle_into_spawn_exact_witness() {
    let handles = "use std::rc::Rc;\n\
                   pub struct Handle {\n\
                   \x20   pub slots: Rc<Vec<u32>>,\n\
                   }\n\
                   pub fn make_handle() -> Handle {\n\
                   \x20   Handle { slots: Rc::new(vec![]) }\n\
                   }\n";
    let engine = "fn start() {\n\
                  \x20   let h = make_handle();\n\
                  \x20   std::thread::spawn(move || {\n\
                  \x20       let _ = h;\n\
                  \x20   });\n\
                  }\n";
    let v = run_sources(&[
        ("rust/src/engine/handles.rs", handles),
        ("rust/src/engine/mod.rs", engine),
    ]);
    assert_eq!(v.len(), 1, "{v:?}");
    assert_eq!(v[0].rule, "thread-escape");
    assert_eq!(v[0].file, "rust/src/engine/mod.rs");
    assert_eq!(v[0].line, 4);
    assert!(
        v[0].msg.contains("`h` carries non-Send state into a spawn"),
        "{}",
        v[0].msg
    );
    assert_eq!(
        v[0].witness,
        vec![
            "rust/src/engine/mod.rs:4: `h` (bound at line 2) is captured by the spawn here"
                .to_string(),
            "rust/src/engine/mod.rs:2: `h` bound from make_handle() returning `Handle`"
                .to_string(),
            "rust/src/engine/handles.rs:3: Handle holds non-Send `Rc`".to_string(),
        ]
    );
}

/// The same tainted helper used entirely on one thread (no spawn/send/
/// Arc::new span) is fine — R8 is value-flow into escape sites, not a
/// blanket Rc ban (the per-worker engine `Runtime` is Rc-based by
/// design).
#[test]
fn r8_tainted_helper_on_one_thread_is_clean() {
    let handles = "use std::rc::Rc;\n\
                   pub struct Handle {\n\
                   \x20   pub slots: Rc<Vec<u32>>,\n\
                   }\n\
                   pub fn make_handle() -> Handle {\n\
                   \x20   Handle { slots: Rc::new(vec![]) }\n\
                   }\n";
    let engine = "fn start() {\n\
                  \x20   let h = make_handle();\n\
                  \x20   drop(h);\n\
                  }\n";
    let v = run_sources(&[
        ("rust/src/engine/handles.rs", handles),
        ("rust/src/engine/mod.rs", engine),
    ]);
    assert!(v.is_empty(), "{v:?}");
}

// ---------------------------------------------------------------------
// R9 stamp-discipline
// ---------------------------------------------------------------------

/// An unmarked pub fn reaching `page_mut` two calls down fires with the
/// exact call chain; the private middleman (under no marked fn) fires
/// too, with its own one-hop chain.
#[test]
fn r9_unmarked_transitive_writer_exact_witness() {
    let kv = "pub struct KvCache {\n\
              \x20   n: usize,\n\
              }\n\
              impl KvCache {\n\
              \x20   fn page_mut(&mut self) -> &mut usize {\n\
              \x20       &mut self.n\n\
              \x20   }\n\
              \x20   fn ensure_page(&mut self) {\n\
              \x20       self.page_mut();\n\
              \x20   }\n\
              \x20   pub fn write_rows(&mut self) {\n\
              \x20       self.ensure_page();\n\
              \x20   }\n\
              }\n";
    let v = run_sources(&[("rust/src/kvcache/mod.rs", kv)]);
    assert_eq!(v.len(), 2, "{v:?}");
    assert!(v.iter().all(|x| x.rule == "stamp-discipline"), "{v:?}");
    // sorted by line: ensure_page (8) then write_rows (11)
    assert_eq!(v[0].line, 8);
    assert!(v[0].msg.contains("private fn `ensure_page`"), "{}", v[0].msg);
    assert_eq!(
        v[0].witness,
        vec!["rust/src/kvcache/mod.rs:9: KvCache::ensure_page -> KvCache::page_mut".to_string()]
    );
    assert_eq!(v[1].line, 11);
    assert!(
        v[1].msg.contains(
            "pub fn `write_rows` reaches page-storage writes through its call chain"
        ),
        "{}",
        v[1].msg
    );
    assert_eq!(
        v[1].witness,
        vec![
            "rust/src/kvcache/mod.rs:12: KvCache::write_rows -> KvCache::ensure_page".to_string(),
            "rust/src/kvcache/mod.rs:9: KvCache::ensure_page -> KvCache::page_mut".to_string(),
        ]
    );
}

/// Marking the pub entry point covers it AND its private helper: the
/// helper sits on a marked fn's call path, so neither fires.
#[test]
fn r9_marked_entry_point_covers_the_chain() {
    let kv = "pub struct KvCache {\n\
              \x20   n: usize,\n\
              }\n\
              impl KvCache {\n\
              \x20   fn page_mut(&mut self) -> &mut usize {\n\
              \x20       &mut self.n\n\
              \x20   }\n\
              \x20   fn ensure_page(&mut self) {\n\
              \x20       self.page_mut();\n\
              \x20   }\n\
              \x20   /// `#[hass::mutates_storage]` — allocates pages\n\
              \x20   pub fn write_rows(&mut self) {\n\
              \x20       self.ensure_page();\n\
              \x20   }\n\
              }\n";
    let v = run_sources(&[("rust/src/kvcache/mod.rs", kv)]);
    assert!(v.is_empty(), "{v:?}");
}

// ---------------------------------------------------------------------
// Baseline workflow (grandfather -> gate on new)
// ---------------------------------------------------------------------

/// End-to-end over the public API: a wire key emitted with no reader is
/// a `wire-dead` warning; `render_updated` grandfathers it, the parsed
/// baseline suppresses exactly that fingerprint, and a genuinely new
/// finding is NOT covered.
#[test]
fn baseline_covers_old_findings_but_not_new_ones() {
    let server = "fn stats_line() -> Json {\n\
                  \x20   Json::obj(vec![(\"queue_ms\", Json::num(1.0))])\n\
                  }\n";
    let v = run_sources(&[("rust/src/server/mod.rs", server)]);
    assert_eq!(v.len(), 1, "{v:?}");
    assert_eq!(v[0].rule, "wire-dead");
    assert_eq!(v[0].severity, "warning");
    assert!(v[0].msg.contains("wire key \"queue_ms\" is emitted but no reader"), "{}", v[0].msg);

    // grandfather the current findings, then re-run with a second dead
    // key: only the new one should be un-baselined
    let baseline = Baseline::parse(&Baseline::default().render_updated(&v));
    assert!(baseline.contains(&fingerprint(&v[0])));
    let server2 = "fn stats_line() -> Json {\n\
                   \x20   Json::obj(vec![(\"queue_ms\", Json::num(1.0)),\n\
                   \x20                  (\"busy_ms\", Json::num(2.0))])\n\
                   }\n";
    let v2 = run_sources(&[("rust/src/server/mod.rs", server2)]);
    let fresh: Vec<_> = v2.iter().filter(|x| !baseline.contains(&fingerprint(x))).collect();
    assert_eq!(fresh.len(), 1, "{fresh:?}");
    assert!(fresh[0].msg.contains("\"busy_ms\""), "{}", fresh[0].msg);
}
