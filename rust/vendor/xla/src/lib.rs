//! Offline stand-in for the `xla` crate (PJRT bindings).
//!
//! The real crate links the XLA C++ runtime, which is not present in the
//! offline build image.  This stand-in keeps the workspace compiling and
//! the artifact-free test suite green:
//!
//! * `Literal` is a **fully functional** host-side container (element
//!   type + dims + little-endian bytes), so tensor round-trips and
//!   checkpoint loading work for real;
//! * the PJRT client/compile/execute path is **gated**: `compile` returns
//!   a descriptive error, so artifact-dependent code paths fail cleanly
//!   and the integration tests skip, exactly as they do when artifacts
//!   are missing.
//!
//! To run with real artifacts, point the `xla` path dependency in the
//! workspace `Cargo.toml` at the real PJRT bindings; the API here is a
//! drop-in subset.

use std::fmt;
use std::path::Path;

#[derive(Clone, Debug)]
pub struct XlaError {
    pub msg: String,
}

impl XlaError {
    fn new(msg: impl Into<String>) -> XlaError {
        XlaError { msg: msg.into() }
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.msg)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

const NO_BACKEND: &str = "PJRT backend not vendored in the offline build; \
graph execution is unavailable (swap rust/vendor/xla for the real `xla` \
crate to execute compiled HLO artifacts)";

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    pub fn byte_width(self) -> usize {
        match self {
            ElementType::F32 | ElementType::S32 => 4,
        }
    }
}

/// Host scalar types storable in a `Literal`.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn to_le(self) -> [u8; 4];
    fn from_le(b: &[u8]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn to_le(self) -> [u8; 4] {
        self.to_le_bytes()
    }
    fn from_le(b: &[u8]) -> f32 {
        f32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn to_le(self) -> [u8; 4] {
        self.to_le_bytes()
    }
    fn from_le(b: &[u8]) -> i32 {
        i32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Host-side literal: element type, dims, raw little-endian bytes.
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    bytes: Vec<u8>,
}

impl Literal {
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { ty: T::TY, dims: vec![], bytes: v.to_le().to_vec() }
    }

    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        // the .max(1) intentionally mirrors runtime/tensor.rs::numel — the
        // sole in-repo literal producer always sizes buffers that way, so
        // a dims-with-zero tensor carries one (padding) element here too
        let numel: usize = dims.iter().product::<usize>().max(1);
        let expect = numel * ty.byte_width();
        if expect != data.len() {
            return Err(XlaError::new(format!(
                "literal shape {dims:?} wants {expect} bytes, got {}",
                data.len()
            )));
        }
        Ok(Literal {
            ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            bytes: data.to_vec(),
        })
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::TY != self.ty {
            return Err(XlaError::new(format!(
                "element type mismatch: literal holds {:?}",
                self.ty
            )));
        }
        Ok(self.bytes.chunks_exact(4).map(T::from_le).collect())
    }

    /// Decompose a tuple literal.  Tuples only come out of PJRT execution,
    /// which the offline stand-in gates, so this is always an error here.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(XlaError::new(NO_BACKEND))
    }
}

pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| XlaError::new(format!("reading {}: {e}", path.display())))?;
        Ok(HloModuleProto { text })
    }
}

pub struct XlaComputation {
    _hlo_text_len: usize,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _hlo_text_len: proto.text.len() }
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError::new(NO_BACKEND))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::new(NO_BACKEND))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError::new(NO_BACKEND))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_literal_roundtrip() {
        let data = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = data.iter().flat_map(|f| f.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), data.to_vec());
        assert_eq!(lit.array_shape().unwrap().dims(), &[3i64]);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_has_empty_dims() {
        let lit = Literal::scalar(7i32);
        assert_eq!(lit.array_shape().unwrap().dims().len(), 0);
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0u8; 4]).is_err()
        );
    }

    #[test]
    fn pjrt_paths_are_gated() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "offline-stub");
        let comp = XlaComputation::from_proto(&HloModuleProto { text: "HloModule m".into() });
        assert!(client.compile(&comp).is_err());
    }
}
