//! Offline vendored stand-in for the `anyhow` crate.
//!
//! The offline build has no registry access, so this crate implements the
//! API subset the workspace uses — `Error`, `Result`, `Context`,
//! `anyhow!`, `bail!` — with the same semantics:
//!
//! * `{}` shows the outermost message, `{:#}` the full `a: b: c` chain,
//!   `{:?}` the outermost message plus a `Caused by:` list;
//! * `?` converts any `std::error::Error + Send + Sync + 'static` and
//!   captures its `source()` chain;
//! * `Context` is implemented for `Result` and `Option`.

use std::fmt;

/// Dynamic error with a context chain (root cause first, outermost last).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.push(context.to_string());
        self
    }

    fn outermost(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("unknown error")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.outermost())?;
        if f.alternate() {
            for msg in self.chain.iter().rev().skip(1) {
                write!(f, ": {msg}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.outermost())?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for msg in self.chain.iter().rev().skip(1) {
                write!(f, "\n    {msg}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        chain.reverse();
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("root {}", "cause");
    }

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e = fails().unwrap_err().context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root cause");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn io_error_source_chain_is_captured() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert_eq!(format!("{e:#}"), "gone");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "inner"));
        let e = r.context("while testing").unwrap_err();
        assert_eq!(format!("{e:#}"), "while testing: inner");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
        assert_eq!(Some(3).context("fine").unwrap(), 3);
    }

    #[test]
    fn anyhow_macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(format!("{a}"), "plain");
        let n = 4;
        let b = anyhow!("n = {}", n);
        assert_eq!(format!("{b}"), "n = 4");
        let c = anyhow!(String::from("owned"));
        assert_eq!(format!("{c}"), "owned");
    }
}
