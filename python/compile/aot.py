"""AOT pipeline: lower every serving graph to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (what the
rust `xla` crate links) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Every graph takes model weights as *runtime arguments* (leading parameters,
in jax pytree-flatten order — the same order `ckpt.py` writes manifests in),
so artifacts are independent of training and rust swaps draft checkpoints
freely.  `artifacts/meta.json` records, per graph: parameter tensor names,
extra input specs, and output specs; plus golden vectors for the rust
integration tests.

Graphs (S=512 cache slots, d=128, L=4, H=4):
  target_prefill        (w…, tokens[S])                          -> feats, kv_k, kv_v, logits
  target_decode_n{1,8,64,128}
                        (w…, kv_k, kv_v, start, tok[N], pos[N], mask[N,S])
                                                                 -> logits, feats, kv_k', kv_v'
  draft_prefill         (w…, wte, tokens[S], tfeats[S,d])        -> kv_k, kv_v, g
  draft_decode_b{4,10,40,80}
                        (w…, wte, kv, start, tok[B], feats[B,d], pos[B], mask[B,S])
                                                                 -> logits, g, kv_k', kv_v'
  sps_prefill / sps_decode_n{1}  — same families for the SpS tiny LM
  medusa_heads          (w…, wte, feats[1,d])                    -> logits[1,4,V]

Masks are i32 (0/1) at the graph boundary (simplest literal type for rust)
and cast to bool internally.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import ckpt, data
from .model import (DRAFT_CFG, SPS_CFG, TARGET_CFG, draft_decode,
                    draft_prefill, gpt_decode, gpt_forward, gpt_prefill,
                    init_draft, init_gpt, init_medusa, medusa_apply)

S = 512  # cache slots
ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

f32, i32 = jnp.float32, jnp.int32


def spec(shape, dtype=f32):
    return jax.ShapeDtypeStruct(shape, dtype)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def param_specs(params):
    return jax.tree_util.tree_map(lambda a: spec(a.shape, a.dtype), params)


def tensor_names(params):
    return [n for n, _ in ckpt.flatten_named(params)]


# ---------------------------------------------------------------------------
# graph definitions
# ---------------------------------------------------------------------------


def build_graphs(decode_ns=(1, 8, 64, 128), draft_bs=(4, 10, 40, 80)):
    """Returns {name: (fn, arg_specs, param_names, input_specs, output_names)}."""
    tcfg, dcfg, scfg = TARGET_CFG, DRAFT_CFG, SPS_CFG
    d, L, H, hd, V = (tcfg.d_model, tcfg.n_layers, tcfg.n_heads,
                      tcfg.d_head, tcfg.vocab)

    tparams = init_gpt(jax.random.PRNGKey(0), tcfg)
    dparams = init_draft(jax.random.PRNGKey(1), dcfg)
    sparams = init_gpt(jax.random.PRNGKey(2), scfg)
    mparams = init_medusa(jax.random.PRNGKey(3), tcfg)

    graphs = {}

    # ---- target ----
    def target_prefill(p, tokens):
        return gpt_prefill(p, tcfg, tokens)

    graphs["target_prefill"] = (
        target_prefill,
        (param_specs(tparams), spec((S,), i32)),
        tensor_names(tparams),
        [("tokens", (S,), "i32")],
        ["feats", "kv_k", "kv_v", "logits"],
    )

    for n in decode_ns:
        def target_decode(p, kv_k, kv_v, start, tok, pos, mask, _n=n):
            return gpt_decode(p, tcfg, kv_k, kv_v, start, tok, pos, mask != 0)

        graphs[f"target_decode_n{n}"] = (
            target_decode,
            (param_specs(tparams), spec((L, S, H, hd)), spec((L, S, H, hd)),
             spec((), i32), spec((n,), i32), spec((n,), i32), spec((n, S), i32)),
            tensor_names(tparams),
            [("kv_k", (L, S, H, hd), "f32"), ("kv_v", (L, S, H, hd), "f32"),
             ("start", (), "i32"), ("tokens", (n,), "i32"),
             ("positions", (n,), "i32"), ("mask", (n, S), "i32")],
            ["logits", "feats", "kv_k", "kv_v"],
        )

    # ---- draft (EAGLE/HASS) ----
    def d_prefill(dp, wte, tokens, tfeats):
        return draft_prefill(dp, wte, dcfg, tokens, tfeats)

    graphs["draft_prefill"] = (
        d_prefill,
        (param_specs(dparams), spec((V, d)), spec((S,), i32), spec((S, d))),
        tensor_names(dparams) + ["wte"],
        [("tokens", (S,), "i32"), ("tfeats", (S, d), "f32")],
        ["kv_k", "kv_v", "g"],
    )

    for b in draft_bs:
        def d_decode(dp, wte, kv_k, kv_v, start, tok, feats, pos, mask, _b=b):
            return draft_decode(dp, wte, dcfg, kv_k, kv_v, start, tok, feats,
                                pos, mask != 0)

        graphs[f"draft_decode_b{b}"] = (
            d_decode,
            (param_specs(dparams), spec((V, d)), spec((S, H, hd)),
             spec((S, H, hd)), spec((), i32), spec((b,), i32), spec((b, d)),
             spec((b,), i32), spec((b, S), i32)),
            tensor_names(dparams) + ["wte"],
            [("kv_k", (S, H, hd), "f32"), ("kv_v", (S, H, hd), "f32"),
             ("start", (), "i32"), ("tokens", (b,), "i32"),
             ("feats", (b, d), "f32"), ("positions", (b,), "i32"),
             ("mask", (b, S), "i32")],
            ["logits", "g", "kv_k", "kv_v"],
        )

    # ---- SpS tiny LM ----
    sL, sH, shd = scfg.n_layers, scfg.n_heads, scfg.d_head

    def sps_prefill(p, tokens):
        return gpt_prefill(p, scfg, tokens)

    graphs["sps_prefill"] = (
        sps_prefill,
        (param_specs(sparams), spec((S,), i32)),
        tensor_names(sparams),
        [("tokens", (S,), "i32")],
        ["feats", "kv_k", "kv_v", "logits"],
    )

    def sps_decode(p, kv_k, kv_v, start, tok, pos, mask):
        return gpt_decode(p, scfg, kv_k, kv_v, start, tok, pos, mask != 0)

    graphs["sps_decode_n1"] = (
        sps_decode,
        (param_specs(sparams), spec((sL, S, sH, shd)), spec((sL, S, sH, shd)),
         spec((), i32), spec((1,), i32), spec((1,), i32), spec((1, S), i32)),
        tensor_names(sparams),
        [("kv_k", (sL, S, sH, shd), "f32"), ("kv_v", (sL, S, sH, shd), "f32"),
         ("start", (), "i32"), ("tokens", (1,), "i32"),
         ("positions", (1,), "i32"), ("mask", (1, S), "i32")],
        ["logits", "feats", "kv_k", "kv_v"],
    )

    # ---- medusa ----
    def medusa(mp, wte, feats):
        return (medusa_apply(mp, wte, feats),)

    graphs["medusa_heads"] = (
        medusa,
        (param_specs(mparams), spec((V, d)), spec((1, d))),
        tensor_names(mparams) + ["wte"],
        [("feats", (1, d), "f32")],
        ["logits"],
    )

    return graphs


# ---------------------------------------------------------------------------
# goldens: greedy continuation + prefill logit fingerprints
# ---------------------------------------------------------------------------


def build_goldens(n_tokens=24):
    """Greedy continuations from the trained target for rust integration
    tests (engine output at T=0 must match these token-for-token)."""
    tparams = jax.tree_util.tree_map(
        jnp.asarray, ckpt.load("target", init_gpt(jax.random.PRNGKey(0), TARGET_CFG)))
    fwd = jax.jit(lambda r: gpt_forward(tparams, TARGET_CFG, r)[1])
    goldens = []
    for prompt in [data.suite("dialogue", 2, seed=31)[0],
                   data.suite("code", 2, seed=32)[0],
                   data.suite("math", 2, seed=33)[0]]:
        ids = data.encode(prompt, bos=True)
        cur = len(ids)
        row = np.zeros(256, np.int32)  # fixed shape: one jit compilation
        row[:cur] = ids
        out = []
        for _ in range(n_tokens):
            logits = np.asarray(fwd(jnp.asarray(row)))
            nxt = int(np.argmax(logits[cur - 1]))
            out.append(nxt)
            row[cur] = nxt
            cur += 1
        # fingerprint: first-8 logits at the last prompt position (the
        # padded row is causal, so position len-1 only sees the prompt)
        logits0 = np.asarray(fwd(jnp.asarray(row)))[len(ids) - 1, :8]
        goldens.append({
            "prompt_tokens": [int(x) for x in ids],
            "greedy_tokens": out,
            "prefill_logits8": [float(x) for x in logits0],
        })
    return goldens


# ---------------------------------------------------------------------------
# main
# ---------------------------------------------------------------------------


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=ART_DIR)
    ap.add_argument("--skip-goldens", action="store_true")
    ap.add_argument("--graphs", default="", help="comma-filter of graph names")
    args = ap.parse_args(argv)

    os.makedirs(args.out_dir, exist_ok=True)
    graphs = build_graphs()
    only = set(args.graphs.split(",")) if args.graphs else None

    meta = {
        "config": {
            "S": S,
            "target": vars(TARGET_CFG) if not hasattr(TARGET_CFG, "__dataclass_fields__")
            else {k: getattr(TARGET_CFG, k) for k in TARGET_CFG.__dataclass_fields__},
            "draft": {k: getattr(DRAFT_CFG, k) for k in DRAFT_CFG.__dataclass_fields__},
            "sps": {k: getattr(SPS_CFG, k) for k in SPS_CFG.__dataclass_fields__},
        },
        "graphs": {},
    }

    for name, (fn, arg_specs, pnames, inputs, outputs) in graphs.items():
        if only and name not in only:
            continue
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        meta["graphs"][name] = {
            "file": f"{name}.hlo.txt",
            "params": pnames,
            "inputs": [{"name": n, "shape": list(s), "dtype": t} for n, s, t in inputs],
            "outputs": outputs,
        }
        print(f"lowered {name}: {len(text)} chars", flush=True)

    if not args.skip_goldens and ckpt.exists("target"):
        meta["goldens"] = build_goldens()
        print("goldens built")
    elif not args.skip_goldens:
        print("WARNING: no target checkpoint; goldens skipped")

    with open(os.path.join(args.out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(f"wrote {os.path.join(args.out_dir, 'meta.json')}")


if __name__ == "__main__":
    main()
