"""Deterministic corpus and evaluation-suite generators.

The paper trains draft models on ShareGPT (68k dialogues) and evaluates on
MT-bench (dialogue), HumanEval (code), GSM8K (math), and five WMT translation
directions.  None of those assets are available offline, so this module builds
the closest synthetic equivalents (see DESIGN.md §2):

* ``train_corpus``      — templated multi-turn dialogues mixed with code and
                          math text; plays the role of ShareGPT.
* ``suite("dialogue")`` — held-out dialogue prompts  (MT-bench stand-in).
* ``suite("code")``     — held-out code prompts      (HumanEval stand-in).
* ``suite("math")``     — held-out math prompts      (GSM8K stand-in).
* ``suite("xl_de" .. "xl_zh")`` — five deterministic cipher-"languages"
                          (translation stand-ins; out-of-domain but regular).

Everything is seeded and reproducible; the rust workload generator
(rust/src/workload/) mirrors the *prompt* side of these generators exactly so
that python-side experiments and the rust serving engine see identical inputs.

Tokenizer: char-level, vocab 128.  ids 0/1/2 = PAD/BOS/EOS, 3 = '?'-fallback,
'\n' = 10, '\t' = 9, printable ASCII 32..126 map to their own byte value.
"""

from __future__ import annotations

import random
from dataclasses import dataclass

VOCAB = 128
PAD, BOS, EOS, UNK = 0, 1, 2, 3


def encode(text: str, bos: bool = False) -> list[int]:
    ids = [BOS] if bos else []
    for ch in text:
        o = ord(ch)
        if o in (9, 10) or 32 <= o <= 126:
            ids.append(o)
        else:
            ids.append(UNK)
    return ids


def decode(ids) -> str:
    out = []
    for i in ids:
        i = int(i)
        if i in (PAD, BOS):
            continue
        if i == EOS:
            break
        if i in (9, 10) or 32 <= i <= 126:
            out.append(chr(i))
        else:
            out.append("?")
    return "".join(out)


# ---------------------------------------------------------------------------
# template pools (shared with the rust mirror — keep in sync with
# rust/src/workload/mod.rs; changing these invalidates trained checkpoints)
# ---------------------------------------------------------------------------

TOPICS = [
    "the weather", "a good book", "machine learning", "baking bread",
    "planets", "music theory", "chess openings", "growing tomatoes",
    "ocean tides", "ancient rome", "bicycles", "photography",
]
NAMES = ["Tom", "Ana", "Raj", "Mia", "Leo", "Sue", "Ben", "Ivy", "Max", "Zoe"]
THINGS = ["apples", "books", "coins", "stamps", "cards", "shells", "pens", "keys"]
ANSWER_STEMS = [
    "That is a great question about {t}. The key idea is that {t} follows a simple pattern, and once you see the pattern it is easy to explain.",
    "Let me explain {t} step by step. First, consider the basics. Second, look at an example. Third, practice a little every day.",
    "Many people ask about {t}. In short, it depends on the details, but the general rule is easy to remember and apply.",
    "Here is a summary of {t}: it is simpler than it looks. Start small, repeat often, and check your results as you go.",
]
QUESTION_STEMS = [
    "Can you tell me about {t}?",
    "What should I know about {t}?",
    "How does {t} work?",
    "Why is {t} interesting?",
]
FUNC_NAMES = ["add", "scale", "merge", "count", "clip", "fold", "rank", "swap"]
CODE_BODIES = [
    "def {f}_items(a, b):\n    \"\"\"Return the {f} of a and b.\"\"\"\n    result = a + b\n    return result\n",
    "def {f}_list(xs):\n    \"\"\"Apply {f} to every item in xs.\"\"\"\n    out = []\n    for x in xs:\n        out.append(x + 1)\n    return out\n",
    "def {f}_value(x, y):\n    \"\"\"Compute the {f} value.\"\"\"\n    if x > y:\n        return x - y\n    return y - x\n",
    "def {f}_total(items):\n    \"\"\"Sum all items after {f}.\"\"\"\n    total = 0\n    for item in items:\n        total = total + item\n    return total\n",
]

# five deterministic "cipher languages": vowel/consonant rotations that keep
# text regular but out of the training distribution (translation stand-ins).
_VOWELS = "aeiou"


def _cipher(text: str, shift: int, swap_case: bool) -> str:
    out = []
    for ch in text:
        lower = ch.lower()
        if lower in _VOWELS:
            idx = (_VOWELS.index(lower) + shift) % 5
            rep = _VOWELS[idx]
            out.append(rep.upper() if ch.isupper() else rep)
        elif swap_case and ch.isalpha():
            out.append(ch.swapcase())
        else:
            out.append(ch)
    return "".join(out)


CIPHERS = {
    "xl_de": (1, False),
    "xl_fr": (2, False),
    "xl_ja": (3, False),
    "xl_ru": (1, True),
    "xl_zh": (2, True),
}


# ---------------------------------------------------------------------------
# document generators
# ---------------------------------------------------------------------------

def dialogue_doc(rng: random.Random, turns: int = 2) -> str:
    parts = []
    for _ in range(turns):
        t = rng.choice(TOPICS)
        q = rng.choice(QUESTION_STEMS).format(t=t)
        a = rng.choice(ANSWER_STEMS).format(t=t)
        parts.append(f"User: {q}\nAssistant: {a}\n")
    return "".join(parts)


def code_doc(rng: random.Random) -> str:
    f = rng.choice(FUNC_NAMES)
    body = rng.choice(CODE_BODIES).format(f=f)
    return f"# Task: implement {f}\n{body}\n"


def math_doc(rng: random.Random) -> str:
    n1, n2 = rng.randint(2, 9), rng.randint(2, 9)
    name = rng.choice(NAMES)
    thing = rng.choice(THINGS)
    op = rng.choice(["buys", "finds", "gets"])
    total = n1 + n2
    return (
        f"Q: {name} has {n1} {thing} and {op} {n2} more. "
        f"How many {thing} does {name} have?\n"
        f"A: {name} starts with {n1} {thing}. {n1} + {n2} = {total}. "
        f"The answer is {total}.\n\n"
    )


def translation_doc(rng: random.Random, lang: str) -> str:
    shift, swap = CIPHERS[lang]
    t = rng.choice(TOPICS)
    src = rng.choice(ANSWER_STEMS).format(t=t)
    cip = _cipher(src, shift, swap)
    tag = lang.split("_")[1].upper()
    return f"[{tag}] {cip}\nEnglish: {src}\n"


def train_corpus(n_docs: int = 2000, seed: int = 1234) -> list[str]:
    """ShareGPT stand-in: 70% dialogue, 15% code, 15% math."""
    rng = random.Random(seed)
    docs = []
    for i in range(n_docs):
        r = rng.random()
        if r < 0.70:
            docs.append(dialogue_doc(rng))
        elif r < 0.85:
            docs.append(code_doc(rng))
        else:
            docs.append(math_doc(rng))
    return docs


def suite(name: str, n_prompts: int = 16, seed: int = 777) -> list[str]:
    """Held-out evaluation prompts.  The prompt is the prefix the engine
    conditions on; generation continues from it."""
    rng = random.Random(seed + hash(name) % 100003)
    prompts = []
    for _ in range(n_prompts):
        if name == "dialogue":
            t = rng.choice(TOPICS)
            q = rng.choice(QUESTION_STEMS).format(t=t)
            prompts.append(f"User: {q}\nAssistant:")
        elif name == "code":
            f = rng.choice(FUNC_NAMES)
            prompts.append(f"# Task: implement {f}\ndef {f}_")
        elif name == "math":
            n1, n2 = rng.randint(2, 9), rng.randint(2, 9)
            name_ = rng.choice(NAMES)
            thing = rng.choice(THINGS)
            prompts.append(
                f"Q: {name_} has {n1} {thing} and buys {n2} more. "
                f"How many {thing} does {name_} have?\nA:"
            )
        elif name in CIPHERS:
            shift, swap = CIPHERS[name]
            t = rng.choice(TOPICS)
            src = rng.choice(ANSWER_STEMS).format(t=t)
            tag = name.split("_")[1].upper()
            prompts.append(f"[{tag}] {_cipher(src, shift, swap)}\nEnglish:")
        else:
            raise ValueError(f"unknown suite {name}")
    return prompts


SUITES = ["dialogue", "code", "math"]
TRANSLATION_SUITES = list(CIPHERS)


@dataclass
class Batcher:
    """Packs documents into fixed-length token rows for training."""

    seq_len: int
    seed: int = 99

    def rows(self, docs: list[str]):
        import numpy as np

        rng = random.Random(self.seed)
        stream: list[int] = []
        rows = []
        docs = list(docs)
        rng.shuffle(docs)
        for d in docs:
            stream.extend(encode(d, bos=True) + [EOS])
            while len(stream) >= self.seq_len:
                rows.append(stream[: self.seq_len])
                stream = stream[self.seq_len :]
        return np.array(rows, dtype=np.int32)
