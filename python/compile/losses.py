"""Training losses: EAGLE base loss + the seven harmonized-objective
distillation losses the paper ablates in Table 3.

All distillation losses take target logits ``zq`` and draft logits ``zp``
([T, V]) plus hyper-parameters, and return a scalar.  q = softmax(zq) is the
teacher (target LLM) distribution, p = softmax(zp) the student (draft).

* ``topk_loss``          — the paper's §3.1 loss: -Σ_{x∈Ω̂} q(x) log p(x)
                           over the K most probable target tokens.
* ``topp_loss``          — Ω̂ = smallest prefix of the sorted target
                           distribution whose cumulative mass ≥ P.
* ``normed_topk_loss``   — both distributions renormalized over Ω̂
                           (linear or softmax normalization).
* ``bidir_topk_loss``    — Ω̂ = top-K(q) ∪ top-K(p).
* ``recallk_loss``       — smooth Recall@k surrogate (Patel et al. 2022):
                           maximize σ((z_p[i] − kth-largest z_p)/τ) for the
                           teacher's top-K tokens.
* ``bild_loss``          — Bi-directional Logits Difference (Li et al.
                           2024a): match pairwise logit *differences* over
                           teacher top-k (t2s) and student top-k (s2t),
                           filtering long-tail noise.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def smooth_l1(x, y):
    d = jnp.abs(x - y)
    return jnp.where(d < 1.0, 0.5 * d * d, d - 0.5).mean()


def soft_ce(zq, zp):
    """Full-vocabulary soft cross-entropy -Σ q log p (EAGLE's logit loss)."""
    q = jax.nn.softmax(zq, axis=-1)
    return -(q * jax.nn.log_softmax(zp, axis=-1)).sum(-1).mean()


def eagle_loss(g, f, zq, zp, w_cls: float = 0.1):
    """EAGLE training loss: feature SmoothL1 + w_cls * soft CE."""
    return smooth_l1(g, f) + w_cls * soft_ce(zq, zp)


# ---------------------------------------------------------------------------
# harmonized objective distillation (Table 3 menu)
# ---------------------------------------------------------------------------


def topk_loss(zq, zp, k: int = 10):
    q = jax.nn.softmax(zq, axis=-1)
    logp = jax.nn.log_softmax(zp, axis=-1)
    topq, idx = jax.lax.top_k(q, k)
    sel_logp = jnp.take_along_axis(logp, idx, axis=-1)
    return -(topq * sel_logp).sum(-1).mean()


def topp_loss(zq, zp, p: float = 0.9):
    q = jax.nn.softmax(zq, axis=-1)
    logp = jax.nn.log_softmax(zp, axis=-1)
    order = jnp.argsort(-q, axis=-1)
    q_sorted = jnp.take_along_axis(q, order, axis=-1)
    logp_sorted = jnp.take_along_axis(logp, order, axis=-1)
    cum = jnp.cumsum(q_sorted, axis=-1)
    # keep tokens until cumulative mass first exceeds p (inclusive)
    keep = (cum - q_sorted) < p
    return -(jnp.where(keep, q_sorted * logp_sorted, 0.0)).sum(-1).mean()


def normed_topk_loss(zq, zp, k: int = 10, norm: str = "linear"):
    q = jax.nn.softmax(zq, axis=-1)
    topq, idx = jax.lax.top_k(q, k)
    zp_sel = jnp.take_along_axis(zp, idx, axis=-1)
    if norm == "linear":
        qn = topq / jnp.maximum(topq.sum(-1, keepdims=True), 1e-30)
        p_sel = jnp.take_along_axis(jax.nn.softmax(zp, axis=-1), idx, axis=-1)
        pn = p_sel / jnp.maximum(p_sel.sum(-1, keepdims=True), 1e-30)
        return -(qn * jnp.log(jnp.maximum(pn, 1e-30))).sum(-1).mean()
    if norm == "softmax":
        zq_sel = jnp.take_along_axis(zq, idx, axis=-1)
        qn = jax.nn.softmax(zq_sel, axis=-1)
        logpn = jax.nn.log_softmax(zp_sel, axis=-1)
        return -(qn * logpn).sum(-1).mean()
    raise ValueError(norm)


def bidir_topk_loss(zq, zp, k: int = 10):
    """Distill over top-K(q) ∪ top-K(p) (union realized as two half-losses;
    the overlap is intentionally counted once per direction, matching the
    'distillation conducted over the most probable tokens w.r.t. the target
    distribution as well as the draft distribution' description)."""
    q = jax.nn.softmax(zq, axis=-1)
    logp = jax.nn.log_softmax(zp, axis=-1)
    _, idx_q = jax.lax.top_k(q, k)
    _, idx_p = jax.lax.top_k(zp, k)
    lq = -(jnp.take_along_axis(q, idx_q, -1) * jnp.take_along_axis(logp, idx_q, -1)).sum(-1)
    lp = -(jnp.take_along_axis(q, idx_p, -1) * jnp.take_along_axis(logp, idx_p, -1)).sum(-1)
    return 0.5 * (lq + lp).mean()


def recallk_loss(zq, zp, k: int = 10, tau: float = 1.0):
    """Smooth Recall@k surrogate: each teacher-top-K token should sit above
    the student's k-th largest logit; sigmoid-relaxed and averaged."""
    _, idx = jax.lax.top_k(zq, k)
    zp_sel = jnp.take_along_axis(zp, idx, axis=-1)
    kth = jax.lax.top_k(zp, k)[0][..., -1:]
    recall = jax.nn.sigmoid((zp_sel - kth) / tau)
    return (1.0 - recall.mean(-1)).mean()


def bild_loss(zq, zp, k: int = 8):
    """Bi-directional logits-difference loss (simplified BiLD).

    Pairwise differences of the top-k logits (teacher-selected for t2s,
    student-selected for s2t) are matched with a soft-CE over difference
    rankings; long-tail tokens never enter (the paper's noise filter).
    """

    def _dir(z_sel_t, z_sel_s):
        dt = z_sel_t[..., :, None] - z_sel_t[..., None, :]
        ds = z_sel_s[..., :, None] - z_sel_s[..., None, :]
        n = dt.shape[-1]
        dt = dt.reshape(*dt.shape[:-2], n * n)
        ds = ds.reshape(*ds.shape[:-2], n * n)
        return -(jax.nn.softmax(dt, -1) * jax.nn.log_softmax(ds, -1)).sum(-1)

    _, idx_t = jax.lax.top_k(zq, k)
    _, idx_s = jax.lax.top_k(zp, k)
    t2s = _dir(jnp.take_along_axis(zq, idx_t, -1), jnp.take_along_axis(zp, idx_t, -1))
    s2t = _dir(jnp.take_along_axis(zq, idx_s, -1), jnp.take_along_axis(zp, idx_s, -1))
    return 0.5 * (t2s + s2t).mean()


LOSS_FNS = {
    "topk": topk_loss,
    "topp": topp_loss,
    "normed_topk_linear": lambda zq, zp, k=10: normed_topk_loss(zq, zp, k, "linear"),
    "normed_topk_softmax": lambda zq, zp, k=10: normed_topk_loss(zq, zp, k, "softmax"),
    "bidir_topk": bidir_topk_loss,
    "recallk": recallk_loss,
    "bild": bild_loss,
    "none": lambda zq, zp, **_: 0.0,
}
