"""L2: pure-JAX model zoo for the HASS reproduction (build-time only).

Everything is a plain pytree of arrays + pure functions, so every serving
graph can be lowered to HLO text with *weights as runtime arguments*
(DESIGN.md §1): rust swaps EAGLE/HASS/ablation checkpoints without
re-compiling artifacts.

Models
------
* GPT target LLM        — 4-layer char-level transformer (LLaMA stand-in).
* EAGLE/HASS draft net  — token-embedding ⊕ previous-feature fusion fc +
                          one transformer layer; logits via the target's
                          (tied) LM head, exactly as in EAGLE/HASS.
* Medusa heads          — K residual-block heads over the target feature.
* SpS tiny LM           — independent 2-layer LM (Vicuna-68M stand-in).

Graph families (used by aot.py)
-------------------------------
* ``gpt_forward``   — full causal forward (training / analysis).
* ``gpt_prefill``   — forward + KV-cache export, serving prefill artifact.
* ``gpt_decode``    — N new tokens vs an S-slot KV cache under an arbitrary
                      [N,S] mask: AR step (N=1), chain verify, tree verify.
* ``draft_prefill`` / ``draft_decode`` — same for the draft net (tree
                      expansion feeds parent *features* alongside tokens).
* ``draft_forward_hca`` — HASS training forward with harmonized context
                      alignment (multi-stream banded attention, L1 kernel).

Attention inner loops call the L1 Pallas kernels (interpret=True) or their
pure-jnp references depending on ``HASS_KERNEL_IMPL`` (env: pallas|ref);
tests assert both lower to identical numerics.
"""

from __future__ import annotations

import os
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import ref as kref
from .kernels.hca_attention import hca_attention
from .kernels.tree_attention import tree_attention


def kernel_impl() -> str:
    return os.environ.get("HASS_KERNEL_IMPL", "pallas")


def _cache_attn(q, k, v, mask):
    if kernel_impl() == "pallas":
        return tree_attention(q, k, v, mask)
    return kref.ref_cache_attention(q, k, v, mask)


def _hca_attn(q, ks, vs):
    if kernel_impl() == "pallas":
        tile = 64 if q.shape[0] % 64 == 0 else q.shape[0]
        return hca_attention(q, ks, vs, q_tile=tile)
    return kref.ref_hca_attention(q, ks, vs)


# ---------------------------------------------------------------------------
# configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class GPTConfig:
    vocab: int = 128
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 512
    max_seq: int = 512

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


TARGET_CFG = GPTConfig()
DRAFT_CFG = GPTConfig(n_layers=1)
SPS_CFG = GPTConfig(d_model=64, n_layers=2, n_heads=2, d_ff=256)

N_MEDUSA_HEADS = 4


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _dense(key, n_in, n_out, scale=0.02):
    return jax.random.normal(key, (n_in, n_out), jnp.float32) * scale


def _block_params(key, cfg: GPTConfig):
    ks = jax.random.split(key, 6)
    d, f = cfg.d_model, cfg.d_ff
    res_scale = 0.02 / (2 * cfg.n_layers) ** 0.5
    return {
        "ln1_g": jnp.ones((d,)), "ln1_b": jnp.zeros((d,)),
        "wq": _dense(ks[0], d, d), "wk": _dense(ks[1], d, d),
        "wv": _dense(ks[2], d, d), "wo": _dense(ks[3], d, d, res_scale),
        "ln2_g": jnp.ones((d,)), "ln2_b": jnp.zeros((d,)),
        "w1": _dense(ks[4], d, f), "b1": jnp.zeros((f,)),
        "w2": _dense(ks[5], f, d, res_scale), "b2": jnp.zeros((d,)),
    }


def init_gpt(key, cfg: GPTConfig):
    keys = jax.random.split(key, cfg.n_layers + 2)
    return {
        "wte": _dense(keys[0], cfg.vocab, cfg.d_model),
        "wpe": _dense(keys[1], cfg.max_seq, cfg.d_model, 0.01),
        "blocks": [_block_params(keys[2 + i], cfg) for i in range(cfg.n_layers)],
        "lnf_g": jnp.ones((cfg.d_model,)), "lnf_b": jnp.zeros((cfg.d_model,)),
    }


def init_draft(key, cfg: GPTConfig = DRAFT_CFG):
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    return {
        "fc": _dense(k1, 2 * d, d, 0.02),
        "fc_b": jnp.zeros((d,)),
        "wpe": _dense(k2, cfg.max_seq, d, 0.01),
        "block": _block_params(k2, cfg),
    }


def init_medusa(key, cfg: GPTConfig = TARGET_CFG, n_heads: int = N_MEDUSA_HEADS):
    d = cfg.d_model
    heads = []
    for _ in range(n_heads):
        k1, k2, key = jax.random.split(key, 3)
        heads.append({
            "w1": _dense(k1, d, d), "b1": jnp.zeros((d,)),
            "w2": _dense(k2, d, d, 0.001),
        })
    return {"heads": heads}


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


def _ln(x, g, b):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * g + b


def _heads(x, cfg: GPTConfig):
    return x.reshape(x.shape[0], cfg.n_heads, cfg.d_head)


def _merge(x):
    return x.reshape(x.shape[0], -1)


def _mlp(b, x):
    return jnp.dot(jax.nn.gelu(jnp.dot(x, b["w1"]) + b["b1"]), b["w2"]) + b["b2"]


def head_logits(params, h):
    """Tied LM head: logits = h @ wte^T (shared by target, draft, medusa)."""
    return jnp.dot(h, params["wte"].T)


# ---------------------------------------------------------------------------
# GPT: full causal forward (training / prefill base)
# ---------------------------------------------------------------------------


def _block_causal(b, x, cfg: GPTConfig):
    t = x.shape[0]
    s = _ln(x, b["ln1_g"], b["ln1_b"])
    q, k, v = (_heads(jnp.dot(s, b[w]), cfg) for w in ("wq", "wk", "wv"))
    mask = jnp.tril(jnp.ones((t, t), bool))
    a = kref.ref_cache_attention(q, k, v, mask)  # plain causal: jnp fast path
    x = x + jnp.dot(_merge(a), b["wo"])
    x = x + _mlp(b, _ln(x, b["ln2_g"], b["ln2_b"]))
    return x


def gpt_forward(params, cfg: GPTConfig, tokens):
    """tokens [T] int32 -> (feats [T,d] post-final-LN, logits [T,V])."""
    t = tokens.shape[0]
    x = params["wte"][tokens] + params["wpe"][:t]
    for b in params["blocks"]:
        x = _block_causal(b, x, cfg)
    h = _ln(x, params["lnf_g"], params["lnf_b"])
    return h, head_logits(params, h)


# ---------------------------------------------------------------------------
# GPT: serving graphs (KV cache, weights-as-args)
# ---------------------------------------------------------------------------


def gpt_prefill(params, cfg: GPTConfig, tokens):
    """tokens [S] -> (feats [S,d], kv_k [L,S,H,hd], kv_v, logits [S,V]).

    Plain causal attention over the padded row: slots past the true prompt
    length hold garbage but are never visible — the decode mask only admits
    slots the engine has actually committed.
    """
    s_len = tokens.shape[0]
    x = params["wte"][tokens] + params["wpe"][:s_len]
    kv_k, kv_v = [], []
    mask = jnp.tril(jnp.ones((s_len, s_len), bool))
    for b in params["blocks"]:
        sx = _ln(x, b["ln1_g"], b["ln1_b"])
        q, k, v = (_heads(jnp.dot(sx, b[w]), cfg) for w in ("wq", "wk", "wv"))
        kv_k.append(k)
        kv_v.append(v)
        a = kref.ref_cache_attention(q, k, v, mask)
        x = x + jnp.dot(_merge(a), b["wo"])
        x = x + _mlp(b, _ln(x, b["ln2_g"], b["ln2_b"]))
    h = _ln(x, params["lnf_g"], params["lnf_b"])
    return h, jnp.stack(kv_k), jnp.stack(kv_v), head_logits(params, h)


def gpt_decode(params, cfg: GPTConfig, kv_k, kv_v, write_start, tokens,
               positions, mask):
    """One incremental step over N new tokens against an S-slot cache.

    kv_k/kv_v [L,S,H,hd]; write_start scalar i32 (slot where the N new KV
    rows go, contiguously); tokens [N] i32; positions [N] i32 (absolute,
    for wpe); mask [N,S] bool — full visibility including the intra-block
    ancestor relation (new token n sits at slot write_start+n).

    Returns (logits [N,V], feats [N,d], kv_k', kv_v').
    """
    x = params["wte"][tokens] + params["wpe"][positions]
    for li, b in enumerate(params["blocks"]):
        sx = _ln(x, b["ln1_g"], b["ln1_b"])
        q, k, v = (_heads(jnp.dot(sx, b[w]), cfg) for w in ("wq", "wk", "wv"))
        kv_k = jax.lax.dynamic_update_slice(kv_k, k[None], (li, write_start, 0, 0))
        kv_v = jax.lax.dynamic_update_slice(kv_v, v[None], (li, write_start, 0, 0))
        a = _cache_attn(q, kv_k[li], kv_v[li], mask)
        x = x + jnp.dot(_merge(a), b["wo"])
        x = x + _mlp(b, _ln(x, b["ln2_g"], b["ln2_b"]))
    h = _ln(x, params["lnf_g"], params["lnf_b"])
    return head_logits(params, h), h, kv_k, kv_v


# ---------------------------------------------------------------------------
# EAGLE/HASS draft net
# ---------------------------------------------------------------------------


def draft_fuse(dparams, wte, tokens, feats):
    """EAGLE fusion: x = fc([emb(token) ; feature])."""
    e = wte[tokens]
    return jnp.dot(jnp.concatenate([e, feats], axis=-1), dparams["fc"]) + dparams["fc_b"]


def shift_feats(target_feats):
    """Input feature at position p is the target feature of p-1 (zeros at 0)."""
    return jnp.concatenate([jnp.zeros_like(target_feats[:1]), target_feats[:-1]], axis=0)


def _draft_tail(b, x, a):
    x2 = x + jnp.dot(_merge(a), b["wo"])
    return x2 + _mlp(b, _ln(x2, b["ln2_g"], b["ln2_b"]))


def draft_forward(dparams, wte, cfg: GPTConfig, tokens, in_feats):
    """Full-causal draft forward (HASS training step 1 == EAGLE training).

    tokens [T]; in_feats [T,d] (already shifted). Returns (g [T,d] feature
    predictions, fused x [T,d] — the residual stream later HASS steps mix
    into their K/V bands).
    """
    t = tokens.shape[0]
    x = draft_fuse(dparams, wte, tokens, in_feats) + dparams["wpe"][:t]
    b = dparams["block"]
    sx = _ln(x, b["ln1_g"], b["ln1_b"])
    q, k, v = (_heads(jnp.dot(sx, b[w]), cfg) for w in ("wq", "wk", "wv"))
    mask = jnp.tril(jnp.ones((t, t), bool))
    a = kref.ref_cache_attention(q, k, v, mask)
    return _draft_tail(b, x, a), x


def draft_forward_hca(dparams, wte, cfg: GPTConfig, tokens, in_feats,
                      prev_fused):
    """HASS training forward step m (m = len(prev_fused)+1 >= 2).

    ``prev_fused`` — fused residual streams x of forwards 1..m-1
    (chronological; x_1 built from target feats), detached by the caller.
    Queries come from the current forward's fused stream; the key/value
    stream per band offset follows Fig. 3 (L1 kernel / ref oracle).

    Returns (g [T,d], fused x [T,d]).
    """
    t = tokens.shape[0]
    x = draft_fuse(dparams, wte, tokens, in_feats) + dparams["wpe"][:t]
    b = dparams["block"]
    streams = list(prev_fused) + [x]  # stream 0 = target-feature forward
    lns = [_ln(s, b["ln1_g"], b["ln1_b"]) for s in streams]
    q = _heads(jnp.dot(lns[-1], b["wq"]), cfg)
    ks = jnp.stack([_heads(jnp.dot(s, b["wk"]), cfg) for s in lns])
    vs = jnp.stack([_heads(jnp.dot(s, b["wv"]), cfg) for s in lns])
    a = _hca_attn(q, ks, vs)
    return _draft_tail(b, x, a), x


def draft_prefill(dparams, wte, cfg: GPTConfig, tokens, target_feats):
    """Serving prefill for the draft net.

    tokens [S]; target_feats [S,d] (unshifted, from gpt_prefill).  Returns
    (kv_k [S,H,hd], kv_v [S,H,hd], g [S,d]).
    """
    in_feats = shift_feats(target_feats)
    s_len = tokens.shape[0]
    x = draft_fuse(dparams, wte, tokens, in_feats) + dparams["wpe"][:s_len]
    b = dparams["block"]
    sx = _ln(x, b["ln1_g"], b["ln1_b"])
    q, k, v = (_heads(jnp.dot(sx, b[w]), cfg) for w in ("wq", "wk", "wv"))
    mask = jnp.tril(jnp.ones((s_len, s_len), bool))
    a = kref.ref_cache_attention(q, k, v, mask)
    return k, v, _draft_tail(b, x, a)


def draft_decode(dparams, wte, cfg: GPTConfig, kv_k, kv_v, write_start,
                 tokens, in_feats, positions, mask):
    """Tree-expansion step: B new draft nodes against the draft KV cache.

    kv_k/kv_v [S,H,hd] (single layer); tokens [B]; in_feats [B,d] (parent
    features); positions [B]; mask [B,S].  Returns (logits [B,V], g [B,d],
    kv_k', kv_v').
    """
    x = draft_fuse(dparams, wte, tokens, in_feats) + dparams["wpe"][positions]
    b = dparams["block"]
    sx = _ln(x, b["ln1_g"], b["ln1_b"])
    q, k, v = (_heads(jnp.dot(sx, b[w]), cfg) for w in ("wq", "wk", "wv"))
    kv_k = jax.lax.dynamic_update_slice(kv_k, k, (write_start, 0, 0))
    kv_v = jax.lax.dynamic_update_slice(kv_v, v, (write_start, 0, 0))
    a = _cache_attn(q, kv_k, kv_v, mask)
    g = _draft_tail(b, x, a)
    return jnp.dot(g, wte.T), g, kv_k, kv_v


# ---------------------------------------------------------------------------
# Medusa heads
# ---------------------------------------------------------------------------


def medusa_apply(mparams, wte, feats):
    """feats [N,d] -> logits [N, n_heads, V]; head k predicts token t+1+k."""
    outs = []
    for hp in mparams["heads"]:
        h = feats + jnp.dot(jax.nn.silu(jnp.dot(feats, hp["w1"]) + hp["b1"]), hp["w2"])
        outs.append(jnp.dot(h, wte.T))
    return jnp.stack(outs, axis=1)
