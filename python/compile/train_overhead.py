"""Figures 9/10/11 harness: HASS training overhead vs alignment steps.

Measures, for align-j ∈ {1..5} (align-1 == EAGLE/EAGLE-2 training):

* **Fig 9  — training speed** (batch/s), measured on this machine;
* **Fig 10 — computational cost** (GFLOPs/batch), analytic, split into the
  paper's constant / attention / others parts (attention accumulates as
  Σ_{i<=j} i across steps; backward = 2 × (attention + others));
* **Fig 11 — memory** (bytes of live activations per batch, analytic
  proxy for the paper's GPU-memory curves; CPU RSS is too noisy to
  attribute).

Paper reference points: Align-3 ≈ +66% wall-clock vs EAGLE-2 average,
≈ 3x FLOPs; memory grows mildly and fits a single H800 at Align-5.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import ckpt, data
from .model import DRAFT_CFG, TARGET_CFG, init_draft, init_gpt
from .train import TRAIN_SEQ, adamw_init, adamw_step, hass_batch_loss


def analytic_flops(align: int, batch: int, seq: int = TRAIN_SEQ):
    """(constant, attention, others, backward) GFLOPs per batch."""
    cfg = DRAFT_CFG
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    t = seq
    # constant: teacher head on target features (independent of align steps)
    constant = 2 * t * d * v
    # per-forward: fuse fc (2d->d), qkvo projections, mlp, head
    proj = 2 * t * (2 * d * d) + 4 * 2 * t * d * d + 2 * 2 * t * d * f + 2 * t * d * v
    # attention: step j attends over j streams' keys -> Σ_{i<=j} i scaling
    attn_unit = 2 * 2 * t * t * d  # QK^T + PV for one stream pair
    attn = sum(range(1, align + 1)) * attn_unit
    others = align * proj
    backward = 2 * (attn + others)
    scale = batch / 1e9
    return constant * scale, attn * scale, others * scale, backward * scale


def activation_bytes(align: int, batch: int, seq: int = TRAIN_SEQ):
    """Live-activation proxy: per-forward residual streams + scores kept
    for backward, plus the detached stream stack reused across steps."""
    cfg = DRAFT_CFG
    d, hgt = cfg.d_model, cfg.n_heads
    t = seq
    per_fwd = (6 * t * d + hgt * t * t) * 4  # activations + attention probs
    streams = align * t * d * 4              # detached fused streams
    return batch * (align * per_fwd + streams)


def main():
    rows = data.Batcher(TRAIN_SEQ).rows(data.train_corpus(60, seed=2))
    tparams = ckpt.load("target", init_gpt(jax.random.PRNGKey(0), TARGET_CFG))
    from .model import gpt_forward

    fwd = jax.jit(lambda r: gpt_forward(tparams, TARGET_CFG, r)[0])
    feats = np.stack([np.asarray(fwd(jnp.asarray(r))) for r in rows[:8]])
    toks = rows[:8]
    wte = jnp.asarray(tparams["wte"])
    batch = 2  # paper's measurement batch size

    print(f"{'align':>6} {'batch/s':>9} {'rel':>6} {'fwdGF':>8} {'bwdGF':>8} "
          f"{'totGF':>8} {'actMB':>7}")
    base_speed = None
    for align in range(1, 6):
        dparams = init_draft(jax.random.PRNGKey(1))
        opt = adamw_init(dparams)

        def batch_loss(dp, tt, ff):
            f = lambda t_, f_: hass_batch_loss(
                dp, wte, t_, f_, align=align, loss_name="topk", k=10, w=1.0,
                beta=1.0, token_align_p=0.0, rngkey=jax.random.PRNGKey(0))
            return jax.vmap(f)(tt, ff).mean()

        @jax.jit
        def step(dp, opt, tt, ff):
            loss, g = jax.value_and_grad(batch_loss)(dp, tt, ff)
            dp, opt = adamw_step(dp, g, opt, 1e-3)
            return dp, opt, loss

        tt = jnp.asarray(toks[:batch])
        ff = jnp.asarray(feats[:batch])
        dparams, opt, _ = step(dparams, opt, tt, ff)  # compile
        n = 6
        t0 = time.time()
        for _ in range(n):
            dparams, opt, loss = step(dparams, opt, tt, ff)
        jax.block_until_ready(loss)
        dt = (time.time() - t0) / n
        speed = 1.0 / dt
        if base_speed is None:
            base_speed = speed
        c, a, o, b = analytic_flops(align, batch)
        mb = activation_bytes(align, batch) / 1e6
        print(f"{align:>6} {speed:>9.2f} {speed / base_speed:>6.2f} "
              f"{c + a + o:>8.2f} {b:>8.2f} {c + a + o + b:>8.2f} {mb:>7.1f}")
    print("\npaper shape: Align-3 ~ +66% time vs Align-1; FLOPs ~3x; "
          "memory grows mildly (Fig 9/10/11).")


if __name__ == "__main__":
    main()
