"""Checkpoint I/O: flat f32 binary + JSON manifest, shared with rust.

The rust runtime (rust/src/runtime/weights.rs) reads the same format.  The
tensor *order* inside the manifest is the jax pytree flatten order
(sorted dict keys / list indices), which is also the order the AOT graphs
expect their weight arguments in — so rust can zip manifest entries with
artifact parameters 1:1.
"""

from __future__ import annotations

import json
import os

import jax
import numpy as np
from jax.tree_util import tree_flatten_with_path

WEIGHTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "weights")


def flatten_named(params):
    """-> list of (name, array) in deterministic pytree-flatten order."""
    flat, _ = tree_flatten_with_path(params)
    return [(jax.tree_util.keystr(path), np.asarray(leaf)) for path, leaf in flat]


def save(name: str, params, meta: dict | None = None, directory: str | None = None):
    directory = directory or WEIGHTS_DIR
    os.makedirs(directory, exist_ok=True)
    named = flatten_named(params)
    manifest = {"tensors": [], "meta": meta or {}}
    offset = 0
    with open(os.path.join(directory, f"{name}.bin"), "wb") as f:
        for tname, arr in named:
            arr = arr.astype(np.float32)
            f.write(arr.tobytes())
            manifest["tensors"].append(
                {"name": tname, "shape": list(arr.shape), "offset": offset}
            )
            offset += arr.size * 4
    with open(os.path.join(directory, f"{name}.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def load(name: str, like_params, directory: str | None = None):
    """Load a checkpoint back into the structure of ``like_params``."""
    directory = directory or WEIGHTS_DIR
    with open(os.path.join(directory, f"{name}.json")) as f:
        manifest = json.load(f)
    raw = np.fromfile(os.path.join(directory, f"{name}.bin"), dtype=np.float32)
    import jax.numpy as jnp

    flat, treedef = jax.tree_util.tree_flatten(like_params)
    arrays = []
    for spec, leaf in zip(manifest["tensors"], flat):
        n = int(np.prod(spec["shape"])) if spec["shape"] else 1
        start = spec["offset"] // 4
        arrays.append(
            jnp.asarray(raw[start : start + n].reshape(spec["shape"]), jnp.float32))
    assert len(arrays) == len(flat), "checkpoint/structure mismatch"
    return jax.tree_util.tree_unflatten(treedef, arrays)


def exists(name: str, directory: str | None = None) -> bool:
    directory = directory or WEIGHTS_DIR
    return os.path.exists(os.path.join(directory, f"{name}.json"))
