"""Training pipeline (build-time): target LM, SpS tiny LM, Medusa heads, and
every EAGLE/HASS draft variant the paper's experiments need.

Variant registry (paper experiment → checkpoint name) is in ``VARIANTS``;
``python -m compile.train --variants hass,eagle`` trains a subset,
``--stage target`` pretrains the target, ``--stage all`` does everything in
dependency order.  Ablation variants continually train from the base
``eagle`` checkpoint, mirroring the paper's Table 4 protocol ("continually
train EAGLE-2's draft model weights").

HASS harmonized context alignment follows the Appendix A.1 pseudo-code:
step m feeds the (detached) feature predictions of step m-1 as inputs and
mixes previous-forward fused streams into the K/V bands via the L1 HCA
attention kernel.  One deviation, documented here: the pseudo-code takes an
optimizer step after *each* alignment forward; we take a single step on the
β-weighted sum Σ_m β^{m-1} L_m — identical gradients up to the (tiny)
intra-batch weight drift, and ~n× faster under jit.
"""

from __future__ import annotations

import argparse
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import ckpt, data
from .losses import LOSS_FNS, smooth_l1, soft_ce
from .model import (DRAFT_CFG, N_MEDUSA_HEADS, SPS_CFG, TARGET_CFG,
                    draft_forward, draft_forward_hca, draft_fuse, gpt_forward,
                    head_logits, init_draft, init_gpt, init_medusa,
                    medusa_apply, shift_feats)

TRAIN_SEQ = 256


# ---------------------------------------------------------------------------
# hand-rolled AdamW (no optax offline)
# ---------------------------------------------------------------------------


def adamw_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros(())}


def adamw_step(params, grads, state, lr, b1=0.9, b2=0.95, eps=1e-8, wd=0.01):
    t = state["t"] + 1.0
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree_util.tree_map(lambda x: x / (1 - b1**t), m)
    vh = jax.tree_util.tree_map(lambda x: x / (1 - b2**t), v)
    new = jax.tree_util.tree_map(
        lambda p, mm, vv: p - lr * (mm / (jnp.sqrt(vv) + eps) + wd * p), params, mh, vh
    )
    return new, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# target LM pretraining
# ---------------------------------------------------------------------------


def lm_loss(params, cfg, tokens):
    """Next-token CE over a [B,T] batch."""

    def one(row):
        _, logits = gpt_forward(params, cfg, row)
        logp = jax.nn.log_softmax(logits[:-1], axis=-1)
        tgt = row[1:]
        return -jnp.take_along_axis(logp, tgt[:, None], axis=-1).mean()

    return jax.vmap(one)(tokens).mean()


def train_lm(cfg, rows, steps, bs, lr, seed=0, log_every=50, name="target"):
    key = jax.random.PRNGKey(seed)
    params = init_gpt(key, cfg)
    opt = adamw_init(params)

    @jax.jit
    def step_fn(params, opt, batch, lr_t):
        loss, grads = jax.value_and_grad(lm_loss)(params, cfg, batch)
        params, opt = adamw_step(params, grads, opt, lr_t)
        return params, opt, loss

    rng = np.random.default_rng(seed)
    t0 = time.time()
    for it in range(steps):
        idx = rng.integers(0, rows.shape[0], bs)
        lr_t = lr * min(1.0, (it + 1) / 40) * (0.1 + 0.9 * (1 - it / steps))
        params, opt, loss = step_fn(params, opt, jnp.asarray(rows[idx]), lr_t)
        if it % log_every == 0 or it == steps - 1:
            print(f"[{name}] step {it:4d} loss {float(loss):.4f} "
                  f"({(time.time()-t0):.0f}s)", flush=True)
    return params


# ---------------------------------------------------------------------------
# feature dataset for draft training
# ---------------------------------------------------------------------------


def build_feature_dataset(tparams, rows, max_rows=1400):
    """Run the target over training rows once; cache post-LN features.

    Returns (tokens [N,T], feats [N,T,d]).  Target logits are re-derived on
    the fly from feats @ wte^T (tied head) during draft training — cheap and
    saves 128/ d× the memory.
    """
    rows = rows[:max_rows]
    fwd = jax.jit(lambda r: gpt_forward(tparams, TARGET_CFG, r)[0])
    feats = []
    bs = 32
    for i in range(0, rows.shape[0], bs):
        feats.append(np.asarray(jax.vmap(fwd)(jnp.asarray(rows[i : i + bs]))))
    return rows, np.concatenate(feats, axis=0)


# ---------------------------------------------------------------------------
# self-distillation corpus (Table 8): greedy generations from the target
# ---------------------------------------------------------------------------


def selfdistill_rows(tparams, n_docs=400, seq=TRAIN_SEQ, seed=5150):
    """Greedy-complete training prompts with the target model and re-pack.

    Uses full re-forward per block of 32 tokens (build-time only, so the
    simple O(T^2) loop is fine at this scale)."""
    import random as pyrandom

    rng = pyrandom.Random(seed)
    fwd = jax.jit(lambda r: gpt_forward(tparams, TARGET_CFG, r)[1])
    docs = []
    for i in range(n_docs):
        r = rng.random()
        if r < 0.7:
            t = rng.choice(data.TOPICS)
            q = rng.choice(data.QUESTION_STEMS).format(t=t)
            prompt = f"User: {q}\nAssistant:"
        elif r < 0.85:
            f = rng.choice(data.FUNC_NAMES)
            prompt = f"# Task: implement {f}\ndef {f}_"
        else:
            n1, n2 = rng.randint(2, 9), rng.randint(2, 9)
            nm, th = rng.choice(data.NAMES), rng.choice(data.THINGS)
            prompt = f"Q: {nm} has {n1} {th} and buys {n2} more. How many {th} does {nm} have?\nA:"
        ids = data.encode(prompt, bos=True)
        ids = ids + [0] * (seq - len(ids)) if len(ids) < seq else ids[:seq]
        cur = len(data.encode(prompt, bos=True))
        ids = np.array(ids, np.int32)
        # greedy continuation, recomputing every 1 token over the full row
        for _ in range(min(160, seq - cur)):
            logits = np.asarray(fwd(jnp.asarray(ids)))
            nxt = int(np.argmax(logits[cur - 1]))
            if nxt == data.EOS:
                break
            ids[cur] = nxt
            cur += 1
        docs.append(data.decode(ids[:cur]))
        if (i + 1) % 50 == 0:
            print(f"[selfdistill] {i+1}/{n_docs} docs", flush=True)
    return data.Batcher(seq).rows(docs)


# ---------------------------------------------------------------------------
# HASS / EAGLE draft training
# ---------------------------------------------------------------------------


def hass_batch_loss(dparams, wte, tokens, f_target, *, align, loss_name, k,
                    w, beta, token_align_p, rngkey, w_cls=0.1):
    """β-weighted sum of per-alignment-step losses for one row.

    tokens [T]; f_target [T,d] (target post-LN features for these tokens).
    """
    cfg = DRAFT_CFG
    zq = jnp.dot(f_target, wte.T)  # teacher logits (dist of next token)
    distill = LOSS_FNS[loss_name]

    f_in = shift_feats(f_target)   # forward-1 inputs
    total = 0.0
    fused_streams = []
    cur_feats = f_in
    cur_tokens = tokens
    g = None
    for m in range(1, align + 1):
        if m == 1:
            g, x = draft_forward(dparams, wte, cfg, cur_tokens, cur_feats)
        else:
            # next forward's inputs: previous predictions, shifted + detached
            cur_feats = jax.lax.stop_gradient(
                jnp.concatenate([f_in[:1], g[:-1]], axis=0))
            if token_align_p > 0.0:
                rngkey, sub = jax.random.split(rngkey)
                draft_tok = jnp.concatenate(
                    [cur_tokens[:1],
                     jnp.argmax(jnp.dot(g[:-1], wte.T), axis=-1)])
                coin = jax.random.bernoulli(sub, token_align_p, cur_tokens.shape)
                cur_tokens = jnp.where(coin, draft_tok, tokens)
            g, x = draft_forward_hca(dparams, wte, cfg, cur_tokens, cur_feats,
                                     fused_streams)
        fused_streams = [jax.lax.stop_gradient(s) for s in fused_streams + [x]]
        zp = jnp.dot(g, wte.T)
        step_loss = (smooth_l1(g, f_target) + w_cls * soft_ce(zq, zp)
                     + w * (distill(zq, zp, k) if loss_name != "none" else 0.0))
        total = total + (beta ** (m - 1)) * step_loss
    return total


def train_draft(name, tokens_ds, feats_ds, wte, *, align=3, loss_name="topk",
                k=10, w=1.0, beta=1.0, token_align_p=0.0, steps=400, bs=4,
                lr=1e-3, seed=1, init_from=None, log_every=50):
    key = jax.random.PRNGKey(seed)
    if init_from is not None and ckpt.exists(init_from):
        dparams = ckpt.load(init_from, init_draft(key))
        print(f"[{name}] continuing from {init_from}")
    else:
        dparams = init_draft(key)
    opt = adamw_init(dparams)

    def batch_loss(dp, toks, feats, rk):
        keys = jax.random.split(rk, toks.shape[0])
        f = partial(hass_batch_loss, dp, wte, align=align, loss_name=loss_name,
                    k=k, w=w, beta=beta, token_align_p=token_align_p)
        return jax.vmap(lambda t_, f_, k_: f(t_, f_, rngkey=k_))(toks, feats, keys).mean()

    @jax.jit
    def step_fn(dp, opt, toks, feats, lr_t, rk):
        loss, grads = jax.value_and_grad(batch_loss)(dp, toks, feats, rk)
        dp, opt = adamw_step(dp, grads, opt, lr_t)
        return dp, opt, loss

    rng = np.random.default_rng(seed)
    t0 = time.time()
    for it in range(steps):
        idx = rng.integers(0, tokens_ds.shape[0], bs)
        lr_t = lr * min(1.0, (it + 1) / 20) * (0.15 + 0.85 * (1 - it / steps))
        key, sub = jax.random.split(key)
        dparams, opt, loss = step_fn(dparams, opt, jnp.asarray(tokens_ds[idx]),
                                     jnp.asarray(feats_ds[idx]), lr_t, sub)
        if it % log_every == 0 or it == steps - 1:
            print(f"[{name}] step {it:4d} loss {float(loss):.4f} "
                  f"({(time.time()-t0):.0f}s)", flush=True)
    meta = {"align": align, "loss": loss_name, "k": k, "w": w, "beta": beta,
            "token_align_p": token_align_p, "steps": steps, "kind": "draft"}
    ckpt.save(name, dparams, meta)
    return dparams


# ---------------------------------------------------------------------------
# Medusa heads
# ---------------------------------------------------------------------------


def train_medusa(tokens_ds, feats_ds, wte, steps=300, bs=8, lr=1e-3, seed=3):
    mparams = init_medusa(jax.random.PRNGKey(seed))
    opt = adamw_init(mparams)

    def loss_fn(mp, toks, feats):
        def one(t_, f_):
            logits = medusa_apply(mp, wte, f_)  # [T, H, V]
            total = 0.0
            tt = t_.shape[0]
            for h in range(N_MEDUSA_HEADS):
                off = h + 1
                lp = jax.nn.log_softmax(logits[: tt - off - 1, h], axis=-1)
                tgt = t_[off + 1 :]
                total += -jnp.take_along_axis(lp, tgt[:, None], 1).mean()
            return total / N_MEDUSA_HEADS

        return jax.vmap(one)(toks, feats).mean()

    @jax.jit
    def step_fn(mp, opt, toks, feats, lr_t):
        loss, grads = jax.value_and_grad(loss_fn)(mp, toks, feats)
        mp, opt = adamw_step(mp, grads, opt, lr_t)
        return mp, opt, loss

    rng = np.random.default_rng(seed)
    for it in range(steps):
        idx = rng.integers(0, tokens_ds.shape[0], bs)
        lr_t = lr * min(1.0, (it + 1) / 20)
        mparams, opt, loss = step_fn(mparams, opt, jnp.asarray(tokens_ds[idx]),
                                     jnp.asarray(feats_ds[idx]), lr_t)
        if it % 50 == 0 or it == steps - 1:
            print(f"[medusa] step {it:4d} loss {float(loss):.4f}", flush=True)
    ckpt.save("medusa", mparams, {"kind": "medusa"})
    return mparams


# ---------------------------------------------------------------------------
# variant registry (paper experiment → checkpoint)
# ---------------------------------------------------------------------------

BASE = dict(align=3, loss_name="topk", k=10, w=1.0, beta=1.0, token_align_p=0.0)

VARIANTS = {
    # main methods (Tables 1/2): eagle == EAGLE & EAGLE-2 weights
    "eagle": dict(align=1, loss_name="none", w=0.0, steps=400),
    "hass": dict(**BASE, steps=400),
    # Table 4: align-step sweep (continual from eagle, like the paper)
    "eagle2_topk": dict(align=1, loss_name="topk", k=10, w=1.0, steps=160, init_from="eagle"),
    "hass_align2": dict(align=2, loss_name="topk", k=10, w=1.0, steps=160, init_from="eagle"),
    "hass_align3": dict(align=3, loss_name="topk", k=10, w=1.0, steps=160, init_from="eagle"),
    "hass_align4": dict(align=4, loss_name="topk", k=10, w=1.0, steps=160, init_from="eagle"),
    "hass_align5": dict(align=5, loss_name="topk", k=10, w=1.0, steps=160, init_from="eagle"),
    # Fig 4 / Table 7: K and w sweeps
    **{f"hass_k{kk}": dict(align=3, loss_name="topk", k=kk, w=1.0, steps=160, init_from="eagle")
       for kk in (1, 5, 50, 100)},
    **{f"hass_w{str(ww).replace('.', '')}": dict(align=3, loss_name="topk", k=10, w=ww,
                                                 steps=160, init_from="eagle")
       for ww in (0.0, 0.1, 0.2, 0.5, 2.0)},
    # Table 3: loss-function menu
    "hass_topp": dict(align=3, loss_name="topp", k=10, w=1.0, steps=160, init_from="eagle"),
    "hass_ntk_lin": dict(align=3, loss_name="normed_topk_linear", k=10, w=1.0, steps=160, init_from="eagle"),
    "hass_ntk_soft": dict(align=3, loss_name="normed_topk_softmax", k=10, w=1.0, steps=160, init_from="eagle"),
    "hass_bidir": dict(align=3, loss_name="bidir_topk", k=10, w=1.0, steps=160, init_from="eagle"),
    "hass_recallk": dict(align=3, loss_name="recallk", k=10, w=1.0, steps=160, init_from="eagle"),
    "hass_bild": dict(align=3, loss_name="bild", k=8, w=1.0, steps=160, init_from="eagle"),
    # Table 5 / Fig 6: β reweighting
    "hass_beta07": dict(align=3, loss_name="topk", k=10, w=1.0, beta=0.7, steps=160, init_from="eagle"),
    "hass_beta05": dict(align=3, loss_name="topk", k=10, w=1.0, beta=0.5, steps=160, init_from="eagle"),
    "hass_beta03": dict(align=3, loss_name="topk", k=10, w=1.0, beta=0.3, steps=160, init_from="eagle"),
    # Table 6 / Fig 7: token alignment
    "hass_tok01": dict(align=3, loss_name="none", w=0.0, token_align_p=0.1, steps=160, init_from="eagle"),
    "hass_tok02": dict(align=3, loss_name="none", w=0.0, token_align_p=0.2, steps=160, init_from="eagle"),
    "hass_tok10": dict(align=3, loss_name="none", w=0.0, token_align_p=1.0, steps=160, init_from="eagle"),
    "hass_featonly": dict(align=3, loss_name="none", w=0.0, steps=160, init_from="eagle"),
    # Table 10 / Fig 8: data proportions (fresh training, scaled steps)
    **{f"eagle_p{p}": dict(align=1, loss_name="none", w=0.0, steps=400, fraction=1.0 / p)
       for p in (2, 4, 8)},
    **{f"hass_p{p}": dict(**BASE, steps=400, fraction=1.0 / p) for p in (2, 4, 8)},
    # Table 8: self-distillation (model-generated data)
    "eagle_mg": dict(align=1, loss_name="none", w=0.0, steps=300, selfdistill=True),
    "hass_mg": dict(**BASE, steps=300, selfdistill=True),
}

CORE = ["eagle", "hass"]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--stage", default="core",
                    help="target|sps|medusa|core|all or comma list of variants")
    ap.add_argument("--steps-scale", type=float, default=1.0)
    ap.add_argument("--target-steps", type=int, default=700)
    ap.add_argument("--docs", type=int, default=2000)
    args = ap.parse_args(argv)

    rows = data.Batcher(TRAIN_SEQ).rows(data.train_corpus(args.docs))
    print(f"corpus rows: {rows.shape}", flush=True)

    def get_target():
        if ckpt.exists("target"):
            return ckpt.load("target", init_gpt(jax.random.PRNGKey(0), TARGET_CFG))
        tp = train_lm(TARGET_CFG, rows, args.target_steps, 8, 3e-3, name="target")
        ckpt.save("target", tp, {"kind": "gpt", "cfg": "target"})
        return tp

    stages = args.stage.split(",")
    want_all = "all" in stages
    if "target" in stages or want_all or "core" in stages:
        tparams = get_target()
    else:
        tparams = ckpt.load("target", init_gpt(jax.random.PRNGKey(0), TARGET_CFG))

    if "sps" in stages or want_all or "core" in stages:
        if not ckpt.exists("sps"):
            sp = train_lm(SPS_CFG, rows, int(500 * args.steps_scale), 8, 3e-3, name="sps")
            ckpt.save("sps", sp, {"kind": "gpt", "cfg": "sps"})

    # feature dataset (shared by draft/medusa training)
    need_feats = want_all or "core" in stages or "medusa" in stages or any(
        s in VARIANTS for s in stages)
    if need_feats:
        print("building feature dataset...", flush=True)
        toks, feats = build_feature_dataset(tparams, rows)
        wte = tparams["wte"]

    if "medusa" in stages or want_all or "core" in stages:
        if not ckpt.exists("medusa"):
            train_medusa(toks, feats, wte, steps=int(300 * args.steps_scale))

    variant_list = [s for s in stages if s in VARIANTS]
    if want_all:
        variant_list = list(VARIANTS)
    elif "core" in stages:
        variant_list = CORE + variant_list

    for vname in variant_list:
        if ckpt.exists(vname):
            print(f"[{vname}] exists, skipping")
            continue
        spec = dict(VARIANTS[vname])
        steps = max(20, int(spec.pop("steps") * args.steps_scale))
        fraction = spec.pop("fraction", 1.0)
        selfd = spec.pop("selfdistill", False)
        if selfd:
            sd_rows = selfdistill_rows(tparams, n_docs=150)
            sd_toks, sd_feats = build_feature_dataset(tparams, sd_rows)
            tt, ff = sd_toks, sd_feats
        elif fraction < 1.0:
            n = max(8, int(toks.shape[0] * fraction))
            tt, ff = toks[:n], feats[:n]
        else:
            tt, ff = toks, feats
        train_draft(vname, tt, ff, wte, steps=steps, **spec)

    print("training done.")


if __name__ == "__main__":
    main()
