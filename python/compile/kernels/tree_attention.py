"""L1 Pallas kernel: draft-tree / KV-cache attention (serving hot-spot).

N new tokens (a flattened draft tree, a verify block, or a single AR step)
attend to an S-slot KV cache under an arbitrary mask that encodes both the
committed-prefix visibility and the intra-tree ancestor relation.  This is
the kernel inside every ``target_verify`` / ``draft_decode`` artifact the
rust engine calls on the request path.

TPU adaptation (DESIGN.md §3): the grid is (heads,); each program instance
keeps its head's full (N, S) score tile in VMEM (N ≤ 128, S ≤ 512 →
≤ 256 KiB f32, well inside the ~16 MiB VMEM budget), computes QK^T on the
MXU, applies the mask via element-wise select (mask streamed from HBM once
per head — it is shared across heads, so a production BlockSpec would pin it
in VMEM across the grid), and fuses masked softmax + PV.  No (N,S,H) mask
materialization in HBM, no per-band gather.

CPU note: lowered with ``interpret=True`` so the emitted HLO runs on the
CPU PJRT plugin (real-TPU lowering emits a Mosaic custom-call).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e9


def _kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, scale):
    # block shapes: q (N, hd), k/v (S, hd), mask (N, S), o (N, hd)
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    m = mask_ref[...]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    scores = jnp.where(m, scores, NEG_INF)
    smax = jnp.max(scores, axis=-1, keepdims=True)
    probs = jnp.exp(scores - smax) * m
    denom = jnp.sum(probs, axis=-1, keepdims=True)
    probs = probs / jnp.maximum(denom, 1e-30)
    o_ref[...] = jnp.dot(probs, v, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=())
def tree_attention(q, k, v, mask):
    """q: [N,H,hd]; k,v: [S,H,hd]; mask: [N,S] bool. Returns [N,H,hd].

    Rows whose mask is all-False produce zeros (padding rows).
    """
    n, h, hd = q.shape
    s = k.shape[0]
    scale = 1.0 / float(hd) ** 0.5
    # head-major layouts for per-head grid programs
    qh = jnp.transpose(q, (1, 0, 2))  # [H,N,hd]
    kh = jnp.transpose(k, (1, 0, 2))  # [H,S,hd]
    vh = jnp.transpose(v, (1, 0, 2))

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale),
        grid=(h,),
        in_specs=[
            pl.BlockSpec((None, n, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, s, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, s, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((n, s), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((None, n, hd), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, n, hd), jnp.float32),
        interpret=True,
    )(qh, kh, vh, mask)
    return jnp.transpose(out, (1, 0, 2))


def vmem_bytes_estimate(n: int, s: int, hd: int) -> int:
    """Per-program VMEM footprint estimate (DESIGN.md §Perf / real-TPU)."""
    f32 = 4
    return (n * hd + 2 * s * hd + 2 * n * s + n * hd) * f32
