"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness signal).

Two attention variants:

* ``ref_cache_attention`` — decode-path attention: N new queries attend to an
  S-slot KV cache under an arbitrary boolean mask (committed-prefix mask +
  draft-tree ancestor mask).  Oracle for ``tree_attention.py``.

* ``ref_hca_attention`` — HASS harmonized-context-alignment attention
  (paper Fig. 3 / Appendix A.1): queries come from the *latest* draft-forward
  hidden states; the key/value at offset ``b = q_pos - k_pos`` is taken from
  the hidden states of forward ``m - b`` (so the self-key uses the current
  forward's own features, offset-1 the previous forward's, ..., falling back
  to the target-feature stream beyond the alignment horizon).  This is
  exactly the feature context the draft model sees at speculation step *m*
  during decoding.  Oracle for ``hca_attention.py``.

Both operate on *post-projection* q/k/v tensors so the oracles pin down the
attention semantics only; projections live in the model (L2).
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e9


def ref_cache_attention(q, k, v, mask):
    """q: [N,H,hd]; k,v: [S,H,hd]; mask: [N,S] bool (True = may attend).

    Returns [N,H,hd]. Rows with no allowed key return zeros (matches kernel).
    """
    hd = q.shape[-1]
    scores = jnp.einsum("nhd,shd->hns", q, k) / jnp.sqrt(jnp.float32(hd))
    scores = jnp.where(mask[None, :, :], scores, NEG_INF)
    any_allowed = mask.any(axis=-1)  # [N]
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs * mask[None, :, :]
    denom = probs.sum(axis=-1, keepdims=True)
    probs = probs / jnp.maximum(denom, 1e-30)
    out = jnp.einsum("hns,shd->nhd", probs, v)
    return out * any_allowed[:, None, None]


def ref_hca_attention(q, k_streams, v_streams):
    """HASS banded multi-stream causal attention.

    q:          [T,H,hd]   — queries from the latest forward's states.
    k_streams:  [M,T,H,hd] — keys per stream; stream 0 = target features,
                             stream i = i-th draft forward (chronological).
    v_streams:  [M,T,H,hd] — values, same layout.

    Key/value for (q_pos p, k_pos t) comes from stream max(M-1-(p-t), 0):
    band 0 (self) -> latest stream M-1, band 1 -> M-2, ..., bands >= M-1 ->
    stream 0 (target features).  Causal: t <= p.
    """
    M, T, H, hd = k_streams.shape
    p_idx = jnp.arange(T)[:, None]
    t_idx = jnp.arange(T)[None, :]
    band = p_idx - t_idx                      # [T,T]
    stream = jnp.maximum(M - 1 - band, 0)     # which stream provides key t
    causal = band >= 0

    # gather per-(p,t) keys/values: k_sel[p,t,h,d] = k_streams[stream[p,t],t]
    k_sel = k_streams[stream, t_idx]          # [T,T,H,hd]
    v_sel = v_streams[stream, t_idx]
    scores = jnp.einsum("phd,pthd->hpt", q, k_sel) / jnp.sqrt(jnp.float32(hd))
    scores = jnp.where(causal[None], scores, NEG_INF)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs * causal[None]
    probs = probs / jnp.maximum(probs.sum(axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("hpt,pthd->phd", probs, v_sel)
    return out


def ref_hca_attention_pseudocode(q, k_streams, v_streams):
    """Direct transliteration of the paper's Appendix A.1 pseudo-code
    (band-*overwrite* formulation) — a second, independently-derived oracle.

    Same signature/semantics as ``ref_hca_attention`` but computed the way
    the paper does it: full attention against the target stream first, then
    per-band score overwrites and a post-softmax value correction.
    """
    M, T, H, hd = k_streams.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    idx = jnp.arange(T)
    causal = idx[:, None] >= idx[None, :]

    k_t, v_t = k_streams[0], v_streams[0]
    attn = jnp.einsum("phd,thd->hpt", q, k_t) * scale      # [H,T,T]
    # draft streams, most recent first (pseudo-code's list[::-1])
    for i in range(M - 1):
        k_d = k_streams[M - 1 - i]
        band = (idx[:, None] - idx[None, :]) == i
        attn_d = jnp.einsum("phd,thd->hpt", q, k_d) * scale
        attn = jnp.where(band[None], attn_d, attn)
    attn = jnp.where(causal[None], attn, NEG_INF)
    w = jnp.exp(attn - attn.max(axis=-1, keepdims=True))
    w = w * causal[None]
    w = w / jnp.maximum(w.sum(axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("hpt,thd->phd", w, v_t)
    for i in range(M - 1):
        v_d = v_streams[M - 1 - i]
        band = ((idx[:, None] - idx[None, :]) == i).astype(w.dtype)
        out = out + jnp.einsum("hpt,thd->phd", w * band[None], v_d - v_t)
    return out
