"""L1 Pallas kernel: HASS harmonized-context-alignment attention (training
hot-spot, paper Fig. 3 / Appendix A.1).

At HASS training step m the draft model must see exactly the feature context
it will see at speculation step m during decoding: its *own* features for the
last m-1 positions and target features before that.  Per (q_pos p, k_pos t)
the key/value stream is ``max(M-1-(p-t), 0)`` where stream 0 holds target
features and streams 1..M-1 the previous draft forwards (chronological).

The paper implements this in PyTorch with M-1 extra full attention matrices
and fancy-indexed band overwrites (Appendix A.1).  Kernel strategy here
(the L1 perf contribution, see DESIGN.md §8):

* one fused kernel, grid (heads, q-tiles);
* the target-stream score tile is computed once on the MXU;
* each of the (M-1) sub-diagonal bands is overwritten via an iota band mask
  against the corresponding draft-stream tile — bands are *sparse* (one
  diagonal each), so the extra MXU work is bounded by (M-1) small matmuls
  per tile instead of M-1 full attention passes;
* masked softmax and the post-softmax value band-correction
  ``out += w·band ⊙ (V_d − V_t)`` are fused in-register (VMEM), never
  materializing [M,T,T] score tensors in HBM.

Lowered with ``interpret=True`` for CPU-PJRT execution (Mosaic is TPU-only).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

NEG_INF = -1e9


def _kernel(pos_ref, q_ref, ks_ref, vs_ref, o_ref, *, scale, m_streams, t_q):
    # blocks: pos (Tq, 1) int32 absolute query positions; q (Tq, hd);
    # ks/vs (M, T, hd); o (Tq, hd); grid (heads, q-tiles).  Positions come in
    # as data rather than pl.program_id so the kernel stays differentiable
    # under interpret-mode autodiff (training uses grads through this).
    q = q_ref[...]
    t_total = ks_ref.shape[1]

    k_t = ks_ref[0]
    v_t = vs_ref[0]
    scores = jnp.dot(q, k_t.T, preferred_element_type=jnp.float32) * scale

    q_pos = pos_ref[...]  # (Tq,1) broadcasts against k_pos
    k_pos = jax.lax.broadcasted_iota(jnp.int32, (t_q, t_total), 1)
    band = q_pos - k_pos
    causal = band >= 0

    # band overwrites: offset i comes from stream M-1-i (most recent first)
    for i in range(m_streams - 1):
        k_d = ks_ref[m_streams - 1 - i]
        s_d = jnp.dot(q, k_d.T, preferred_element_type=jnp.float32) * scale
        scores = jnp.where(band == i, s_d, scores)

    scores = jnp.where(causal, scores, NEG_INF)
    smax = jnp.max(scores, axis=-1, keepdims=True)
    w = jnp.exp(scores - smax) * causal
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-30)

    out = jnp.dot(w, v_t, preferred_element_type=jnp.float32)
    for i in range(m_streams - 1):
        v_d = vs_ref[m_streams - 1 - i]
        wb = jnp.where(band == i, w, 0.0)
        out = out + jnp.dot(wb, v_d - v_t, preferred_element_type=jnp.float32)
    o_ref[...] = out


@functools.lru_cache(maxsize=None)
def _hca_vjp_wrapped(q_tile: int):
    """Pallas forward + reference-graph backward.

    Interpret-mode pallas_call does not support reverse-mode autodiff, so —
    as with production flash-attention kernels — the kernel declares a
    custom VJP.  The backward pass differentiates the pure-jnp reference
    (``ref.ref_hca_attention``), which tests assert is numerically identical
    to the kernel forward.
    """

    @jax.custom_vjp
    def fn(q, ks, vs):
        return _hca_forward(q, ks, vs, q_tile)

    def fwd(q, ks, vs):
        return fn(q, ks, vs), (q, ks, vs)

    def bwd(res, ct):
        q, ks, vs = res
        _, vjp = jax.vjp(ref.ref_hca_attention, q, ks, vs)
        return vjp(ct)

    fn.defvjp(fwd, bwd)
    return fn


def hca_attention(q, k_streams, v_streams, *, q_tile: int = 64):
    """q: [T,H,hd]; k_streams/v_streams: [M,T,H,hd]. Returns [T,H,hd].

    Semantics identical to ``ref.ref_hca_attention``; differentiable via a
    custom VJP (see ``_hca_vjp_wrapped``).
    """
    return _hca_vjp_wrapped(q_tile)(q, k_streams, v_streams)


def _hca_forward(q, k_streams, v_streams, q_tile: int):
    t, h, hd = q.shape
    m = k_streams.shape[0]
    scale = 1.0 / float(hd) ** 0.5
    t_q = min(q_tile, t)
    assert t % t_q == 0, f"T={t} must be divisible by q_tile={t_q}"

    qh = jnp.transpose(q, (1, 0, 2))                    # [H,T,hd]
    ksh = jnp.transpose(k_streams, (2, 0, 1, 3))        # [H,M,T,hd]
    vsh = jnp.transpose(v_streams, (2, 0, 1, 3))
    pos = jnp.arange(t, dtype=jnp.int32)[:, None]       # [T,1]

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, m_streams=m, t_q=t_q),
        grid=(h, t // t_q),
        in_specs=[
            pl.BlockSpec((t_q, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((None, t_q, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, m, t, hd), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((None, m, t, hd), lambda i, j: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, t_q, hd), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((h, t, hd), jnp.float32),
        interpret=True,
    )(pos, qh, ksh, vsh)
    return jnp.transpose(out, (1, 0, 2))


def flops_estimate(t: int, hd: int, h: int, m: int) -> int:
    """Analytic FLOPs: one full QK^T+PV plus (M-1) band matmul pairs."""
    full = 2 * 2 * t * t * hd
    bands = (m - 1) * 2 * 2 * t * t * hd  # upper bound; bands are diag-sparse
    return h * (full + bands)
