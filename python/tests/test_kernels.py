"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

hypothesis sweeps shapes / stream counts / masks; the HCA kernel is also
checked against the *independently derived* Appendix-A.1 pseudo-code oracle
(band-overwrite formulation), so a shared bug in kernel+ref would have to
appear in two very different formulations to pass.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref as kref
from compile.kernels.hca_attention import hca_attention
from compile.kernels.tree_attention import tree_attention

SET = dict(deadline=None, max_examples=12)


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


# ---------------------------------------------------------------------------
# tree / cache attention
# ---------------------------------------------------------------------------


@settings(**SET)
@given(
    n=st.sampled_from([1, 4, 13, 64]),
    s=st.sampled_from([16, 128, 512]),
    h=st.sampled_from([1, 4]),
    hd=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_tree_attention_matches_ref(n, s, h, hd, seed):
    rng = np.random.default_rng(seed)
    q, k, v = rand(rng, n, h, hd), rand(rng, s, h, hd), rand(rng, s, h, hd)
    mask = jnp.asarray(rng.random((n, s)) < 0.4)
    got = tree_attention(q, k, v, mask)
    want = kref.ref_cache_attention(q, k, v, mask)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_tree_attention_all_masked_rows_zero():
    rng = np.random.default_rng(0)
    q, k, v = rand(rng, 3, 2, 8), rand(rng, 16, 2, 8), rand(rng, 16, 2, 8)
    mask = jnp.zeros((3, 16), bool).at[1, :4].set(True)
    out = tree_attention(q, k, v, mask)
    assert float(jnp.abs(out[0]).max()) == 0.0
    assert float(jnp.abs(out[2]).max()) == 0.0
    assert float(jnp.abs(out[1]).max()) > 0.0


def test_tree_attention_single_key_returns_value():
    rng = np.random.default_rng(1)
    q, k, v = rand(rng, 2, 1, 4), rand(rng, 8, 1, 4), rand(rng, 8, 1, 4)
    mask = jnp.zeros((2, 8), bool).at[0, 5].set(True).at[1, 2].set(True)
    out = tree_attention(q, k, v, mask)
    np.testing.assert_allclose(out[0, 0], v[5, 0], atol=1e-6)
    np.testing.assert_allclose(out[1, 0], v[2, 0], atol=1e-6)


def test_tree_attention_causal_equals_softmax_attention():
    """With a plain causal mask the kernel is ordinary causal attention."""
    rng = np.random.default_rng(2)
    t, h, hd = 16, 2, 8
    q, k, v = rand(rng, t, h, hd), rand(rng, t, h, hd), rand(rng, t, h, hd)
    mask = jnp.tril(jnp.ones((t, t), bool))
    got = tree_attention(q, k, v, mask)
    scores = jnp.einsum("nhd,shd->hns", q, k) / np.sqrt(hd)
    scores = jnp.where(mask[None], scores, -1e9)
    want = jnp.einsum("hns,shd->nhd", jax.nn.softmax(scores, -1), v)
    np.testing.assert_allclose(got, want, atol=1e-5)


# ---------------------------------------------------------------------------
# HCA attention
# ---------------------------------------------------------------------------


@settings(**SET)
@given(
    t=st.sampled_from([8, 32, 64]),
    m=st.integers(1, 5),
    h=st.sampled_from([1, 4]),
    hd=st.sampled_from([8, 32]),
    tile=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hca_matches_both_oracles(t, m, h, hd, tile, seed):
    rng = np.random.default_rng(seed)
    q = rand(rng, t, h, hd)
    ks, vs = rand(rng, m, t, h, hd), rand(rng, m, t, h, hd)
    got = hca_attention(q, ks, vs, q_tile=min(tile, t))
    ref1 = kref.ref_hca_attention(q, ks, vs)
    ref2 = kref.ref_hca_attention_pseudocode(q, ks, vs)
    np.testing.assert_allclose(got, ref1, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(ref1, ref2, atol=2e-5, rtol=2e-5)


def test_hca_single_stream_is_plain_causal():
    """M=1 (EAGLE training step 1) must reduce to vanilla causal attention."""
    rng = np.random.default_rng(3)
    t, h, hd = 24, 2, 16
    q = rand(rng, t, h, hd)
    kv = rand(rng, 1, t, h, hd), rand(rng, 1, t, h, hd)
    got = hca_attention(q, *kv, q_tile=t)
    want = kref.ref_cache_attention(q, kv[0][0], kv[1][0],
                                    jnp.tril(jnp.ones((t, t), bool)))
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_hca_band_semantics_first_rows_use_target_stream():
    """Rows p < band offset can only see target-stream keys: row 0 always
    attends to stream M-1 at itself... band 0 uses the *latest* stream, so
    check instead: with M=2, key at (p, t) with p-t>=1 must come from the
    target stream — perturbing draft-stream keys at those slots is a no-op."""
    rng = np.random.default_rng(4)
    t, h, hd, m = 12, 1, 8, 2
    q = rand(rng, t, h, hd)
    ks, vs = rand(rng, m, t, h, hd), rand(rng, m, t, h, hd)
    base = kref.ref_hca_attention(q, ks, vs)
    # perturb draft stream (stream 1) everywhere EXCEPT the diagonal usage:
    # entry t of stream1 is only read by query p == t (band 0).  Query rows
    # see stream-1 keys only on their own diagonal, so zeroing stream-1 key
    # at position j changes only output row j.
    j = 5
    ks2 = ks.at[1, j].add(10.0)
    out2 = kref.ref_hca_attention(q, ks2, vs)
    diff = jnp.abs(out2 - base).max(axis=(1, 2))
    assert float(diff[j]) > 1e-4
    assert float(jnp.delete(diff, j).max()) < 1e-6


def test_hca_gradient_matches_ref_gradient():
    rng = np.random.default_rng(5)
    t, h, hd, m = 16, 2, 8, 3
    q = rand(rng, t, h, hd)
    ks, vs = rand(rng, m, t, h, hd), rand(rng, m, t, h, hd)

    g1 = jax.grad(lambda a, b, c: hca_attention(a, b, c, q_tile=t).sum(),
                  argnums=(0, 1, 2))(q, ks, vs)
    g2 = jax.grad(lambda a, b, c: kref.ref_hca_attention(a, b, c).sum(),
                  argnums=(0, 1, 2))(q, ks, vs)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=1e-5)
