"""Distillation-loss properties (Table 3 menu)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.losses import (LOSS_FNS, bidir_topk_loss, bild_loss, eagle_loss,
                            normed_topk_loss, recallk_loss, smooth_l1,
                            soft_ce, topk_loss, topp_loss)

V = 64
SET = dict(deadline=None, max_examples=15)


def logits(seed, t=6, v=V):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.normal(size=(t, v)) * 2, jnp.float32),
            jnp.asarray(rng.normal(size=(t, v)) * 2, jnp.float32))


@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1))
def test_topk_with_full_vocab_equals_soft_ce(seed):
    zq, zp = logits(seed)
    np.testing.assert_allclose(topk_loss(zq, zp, k=V), soft_ce(zq, zp),
                               rtol=1e-5, atol=1e-5)


@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1))
def test_topp_with_p1_equals_soft_ce(seed):
    zq, zp = logits(seed)
    np.testing.assert_allclose(topp_loss(zq, zp, p=1.0), soft_ce(zq, zp),
                               rtol=1e-4, atol=1e-4)


@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1), k=st.sampled_from([1, 5, 10]))
def test_topk_monotone_in_k(seed, k):
    """Adding more (positive) terms can only increase the truncated CE."""
    zq, zp = logits(seed)
    assert float(topk_loss(zq, zp, k)) <= float(topk_loss(zq, zp, k + 5)) + 1e-6


@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1))
def test_losses_finite_and_nonnegative(seed):
    zq, zp = logits(seed)
    for name, fn in LOSS_FNS.items():
        val = float(fn(zq, zp)) if name != "none" else 0.0
        assert np.isfinite(val), name
        assert val >= -1e-6, name


def test_normed_topk_minimized_when_student_matches_teacher():
    zq, _ = logits(0)
    # student == teacher should (near-)minimize the renormalized CE
    at_match = float(normed_topk_loss(zq, zq, 10, "softmax"))
    worse = float(normed_topk_loss(zq, zq + jnp.flip(zq, -1), 10, "softmax"))
    assert at_match < worse


def test_recallk_zero_when_student_ranks_teacher_topk_high():
    zq, _ = logits(1)
    # student = teacher scaled up -> teacher top-k far above student kth logit
    val = float(recallk_loss(zq, zq * 50, k=5, tau=0.1))
    assert val < 0.25


def test_recallk_bounded():
    zq, zp = logits(2)
    v = float(recallk_loss(zq, zp))
    assert 0.0 <= v <= 1.0


def test_bild_minimal_at_match():
    zq, _ = logits(3)
    at_match = float(bild_loss(zq, zq))
    rng = np.random.default_rng(4)
    pert = zq + jnp.asarray(rng.normal(size=zq.shape), jnp.float32)
    assert at_match <= float(bild_loss(zq, pert)) + 1e-6


def test_bidir_between_halves():
    zq, zp = logits(5)
    b = float(bidir_topk_loss(zq, zp, 10))
    assert np.isfinite(b) and b > 0


@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1))
def test_all_losses_differentiable(seed):
    zq, zp = logits(seed)
    for name, fn in LOSS_FNS.items():
        if name == "none":
            continue
        g = jax.grad(lambda z: fn(zq, z))(zp)
        assert bool(jnp.isfinite(g).all()), name


def test_eagle_loss_components():
    rng = np.random.default_rng(6)
    g = jnp.asarray(rng.normal(size=(5, 8)), jnp.float32)
    f = jnp.asarray(rng.normal(size=(5, 8)), jnp.float32)
    zq, zp = logits(7, t=5, v=16)
    full = float(eagle_loss(g, f, zq, zp, w_cls=0.1))
    assert abs(full - (float(smooth_l1(g, f)) + 0.1 * float(soft_ce(zq, zp)))) < 1e-6
    assert float(eagle_loss(f, f, zq, zq, w_cls=0.0)) < 1e-8 + 1e-6


def test_smooth_l1_regions():
    assert float(smooth_l1(jnp.zeros(1), jnp.asarray([0.5]))) == pytest.approx(0.125)
    assert float(smooth_l1(jnp.zeros(1), jnp.asarray([2.0]))) == pytest.approx(1.5)
