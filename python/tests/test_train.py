"""Training-pipeline invariants (fast, tiny-scale)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import ckpt, data, train
from compile.model import (DRAFT_CFG, TARGET_CFG, draft_forward, gpt_forward,
                           init_draft, init_gpt, shift_feats)
from compile.losses import smooth_l1, soft_ce, topk_loss


@pytest.fixture(scope="module")
def tiny():
    rows = data.Batcher(64).rows(data.train_corpus(40, seed=11))
    tp = init_gpt(jax.random.PRNGKey(0), TARGET_CFG)
    return rows, tp


def test_lm_loss_near_uniform_at_init(tiny):
    rows, tp = tiny
    loss = float(train.lm_loss(tp, TARGET_CFG, jnp.asarray(rows[:2])))
    assert abs(loss - np.log(128)) < 0.5


def test_adamw_step_moves_params(tiny):
    rows, tp = tiny
    opt = train.adamw_init(tp)
    loss, grads = jax.value_and_grad(train.lm_loss)(tp, TARGET_CFG,
                                                    jnp.asarray(rows[:2]))
    tp2, opt2 = train.adamw_step(tp, grads, opt, 1e-3)
    assert float(jnp.abs(tp2["wte"] - tp["wte"]).max()) > 0
    assert float(opt2["t"]) == 1.0


def test_short_training_reduces_loss(tiny):
    rows, _ = tiny
    cfg = TARGET_CFG
    p0 = init_gpt(jax.random.PRNGKey(1), cfg)
    l0 = float(train.lm_loss(p0, cfg, jnp.asarray(rows[:4])))
    p1 = train.train_lm(cfg, rows, steps=12, bs=4, lr=3e-3, log_every=100,
                        name="test")
    l1 = float(train.lm_loss(p1, cfg, jnp.asarray(rows[:4])))
    assert l1 < l0 - 0.3


def test_hass_loss_align1_equals_eagle_components(tiny):
    """align=1, w=0 reduces exactly to the EAGLE loss on forward 1."""
    rows, tp = tiny
    toks = jnp.asarray(rows[0])
    f, _ = gpt_forward(tp, TARGET_CFG, toks)
    dp = init_draft(jax.random.PRNGKey(2))
    got = float(train.hass_batch_loss(
        dp, tp["wte"], toks, f, align=1, loss_name="none", k=10, w=0.0,
        beta=1.0, token_align_p=0.0, rngkey=jax.random.PRNGKey(0)))
    g, _ = draft_forward(dp, tp["wte"], DRAFT_CFG, toks, shift_feats(f))
    zq, zp = jnp.dot(f, tp["wte"].T), jnp.dot(g, tp["wte"].T)
    want = float(smooth_l1(g, f) + 0.1 * soft_ce(zq, zp))
    assert abs(got - want) < 1e-5


def test_hass_loss_beta_weighting(tiny):
    """β=0 keeps only the first alignment step's loss."""
    rows, tp = tiny
    toks = jnp.asarray(rows[0])
    f, _ = gpt_forward(tp, TARGET_CFG, toks)
    dp = init_draft(jax.random.PRNGKey(3))
    kw = dict(loss_name="topk", k=10, w=1.0, token_align_p=0.0,
              rngkey=jax.random.PRNGKey(0))
    l1 = float(train.hass_batch_loss(dp, tp["wte"], toks, f, align=1,
                                     beta=1.0, **kw))
    l3b0 = float(train.hass_batch_loss(dp, tp["wte"], toks, f, align=3,
                                       beta=0.0, **kw))
    assert abs(l1 - l3b0) < 1e-5


def test_hass_loss_align3_grads_finite(tiny):
    rows, tp = tiny
    toks = jnp.asarray(rows[0])
    f, _ = gpt_forward(tp, TARGET_CFG, toks)
    dp = init_draft(jax.random.PRNGKey(4))
    g = jax.grad(lambda d: train.hass_batch_loss(
        d, tp["wte"], toks, f, align=3, loss_name="topk", k=10, w=1.0,
        beta=0.7, token_align_p=0.0, rngkey=jax.random.PRNGKey(0)))(dp)
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree_util.tree_leaves(g))


def test_token_alignment_path_runs(tiny):
    rows, tp = tiny
    toks = jnp.asarray(rows[0])
    f, _ = gpt_forward(tp, TARGET_CFG, toks)
    dp = init_draft(jax.random.PRNGKey(5))
    v = float(train.hass_batch_loss(
        dp, tp["wte"], toks, f, align=2, loss_name="none", k=10, w=0.0,
        beta=1.0, token_align_p=0.5, rngkey=jax.random.PRNGKey(9)))
    assert np.isfinite(v)


def test_ckpt_roundtrip(tmp_path, tiny):
    _, tp = tiny
    ckpt.save("rt", tp, {"kind": "gpt"}, directory=str(tmp_path))
    tp2 = ckpt.load("rt", tp, directory=str(tmp_path))
    for a, b in zip(jax.tree_util.tree_leaves(tp), jax.tree_util.tree_leaves(tp2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ckpt_manifest_order_matches_flatten(tmp_path, tiny):
    _, tp = tiny
    ckpt.save("ord", tp, directory=str(tmp_path))
    import json
    man = json.load(open(tmp_path / "ord.json"))
    names = [t["name"] for t in man["tensors"]]
    assert names == [n for n, _ in ckpt.flatten_named(tp)]
    # offsets are contiguous
    off = 0
    for t in man["tensors"]:
        assert t["offset"] == off
        off += int(np.prod(t["shape"]) if t["shape"] else 1) * 4


def test_variant_registry_covers_paper_experiments():
    v = train.VARIANTS
    assert {"eagle", "hass", "eagle2_topk"} <= set(v)
    assert {f"hass_align{i}" for i in (2, 3, 4, 5)} <= set(v)
    assert {"hass_beta07", "hass_beta05", "hass_beta03"} <= set(v)
    assert {"hass_topp", "hass_bild", "hass_recallk", "hass_bidir"} <= set(v)
    assert {"hass_mg", "eagle_mg"} <= set(v)
    assert {f"hass_p{p}" for p in (2, 4, 8)} <= set(v)
    for name, spec in v.items():
        assert 1 <= spec.get("align", 1) <= 5, name
