"""Fig 9/10/11 analytic-model invariants."""

from compile.train_overhead import activation_bytes, analytic_flops


def test_flops_monotone_in_align():
    prev = 0.0
    for align in range(1, 6):
        c, a, o, b = analytic_flops(align, batch=2)
        total = c + a + o + b
        assert total > prev
        prev = total


def test_constant_part_is_constant():
    c1 = analytic_flops(1, 2)[0]
    c5 = analytic_flops(5, 2)[0]
    assert c1 == c5


def test_attention_part_superlinear():
    # attention scales with sum(1..j): align-4 / align-2 should be 10/3
    a2 = analytic_flops(2, 1)[1]
    a4 = analytic_flops(4, 1)[1]
    assert abs(a4 / a2 - 10.0 / 3.0) < 1e-6


def test_backward_is_twice_attn_plus_others():
    c, a, o, b = analytic_flops(3, 4)
    assert abs(b - 2 * (a + o)) < 1e-9


def test_memory_linear_in_batch_and_growing_in_align():
    assert activation_bytes(3, 4) == 2 * activation_bytes(3, 2)
    assert activation_bytes(4, 2) > activation_bytes(2, 2)
