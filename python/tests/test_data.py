"""Corpus / suite generator determinism and tokenizer roundtrip."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import data


def test_encode_decode_roundtrip_ascii():
    s = "User: hello\nAssistant: 42 + 1 = 43\t(done)"
    assert data.decode(data.encode(s)) == s


@settings(deadline=None, max_examples=30)
@given(st.text(alphabet=st.characters(min_codepoint=32, max_codepoint=126), max_size=80))
def test_roundtrip_printable(s):
    assert data.decode(data.encode(s)) == s


def test_non_ascii_maps_to_unk():
    ids = data.encode("héllo")
    assert data.UNK in ids
    assert data.decode(ids) == "h?llo"


def test_bos_eos_handling():
    ids = data.encode("ab", bos=True) + [data.EOS] + data.encode("cd")
    assert data.decode(ids) == "ab"  # EOS terminates


def test_train_corpus_deterministic():
    a = data.train_corpus(50, seed=7)
    b = data.train_corpus(50, seed=7)
    assert a == b
    assert a != data.train_corpus(50, seed=8)


def test_corpus_mixture():
    docs = data.train_corpus(300, seed=1)
    n_code = sum("def " in d for d in docs)
    n_math = sum(d.startswith("Q:") for d in docs)
    n_dlg = sum(d.startswith("User:") for d in docs)
    assert n_dlg > n_code > 0 and n_math > 0
    assert n_dlg + n_code + n_math == len(docs)


def test_suites_deterministic_and_distinct():
    for name in data.SUITES + data.TRANSLATION_SUITES:
        p1 = data.suite(name, 8)
        p2 = data.suite(name, 8)
        assert p1 == p2
        assert len(set(p1)) > 1


def test_suite_prompts_fit_vocab():
    for name in data.SUITES + data.TRANSLATION_SUITES:
        for p in data.suite(name, 8):
            ids = data.encode(p, bos=True)
            assert all(0 <= i < data.VOCAB for i in ids)
            assert len(ids) < 200  # prompts must fit the 512-slot cache


def test_cipher_deterministic_and_reversible_vowels():
    src = "the quick brown fox"
    c1 = data._cipher(src, 1, False)
    assert c1 != src
    # shifting 5 times returns vowels to the start
    back = src
    for _ in range(5):
        back = data._cipher(back, 1, False)
    assert back == src


def test_ciphers_distinct():
    src = "speculative sampling is fun"
    outs = {data._cipher(src, s, w) for s, w in data.CIPHERS.values()}
    assert len(outs) == len(data.CIPHERS)


def test_batcher_shapes():
    rows = data.Batcher(64).rows(data.train_corpus(30, seed=2))
    assert rows.ndim == 2 and rows.shape[1] == 64
    assert rows.dtype == np.int32
    assert (rows >= 0).all() and (rows < data.VOCAB).all()
