"""L2 model graph correctness.

The crucial invariants for a *lossless* speculative serving engine:

1. prefill and full forward agree;
2. incremental decode with a KV cache reproduces the full forward exactly;
3. *tree* decode: every node's logits equal the full forward of its own
   root-to-node path — this is what makes tree verification sound;
4. draft-model chain decode agrees with the draft training forward
   (training/decoding context harmony for step 1).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (DRAFT_CFG, SPS_CFG, TARGET_CFG, draft_decode,
                           draft_forward, draft_prefill, gpt_decode,
                           gpt_forward, gpt_prefill, init_draft, init_gpt,
                           init_medusa, medusa_apply, shift_feats)

S = 96  # small cache for tests


@pytest.fixture(scope="module")
def target():
    return init_gpt(jax.random.PRNGKey(0), TARGET_CFG)


@pytest.fixture(scope="module")
def draft():
    return init_draft(jax.random.PRNGKey(1), DRAFT_CFG)


def toks(n, seed=0):
    return jnp.asarray(np.random.default_rng(seed).integers(4, 120, n), jnp.int32)


def pad_cache(kvk, kvv, s=S):
    L, t, H, hd = kvk.shape
    zk = jnp.zeros((L, s, H, hd)).at[:, :t].set(kvk)
    zv = jnp.zeros((L, s, H, hd)).at[:, :t].set(kvv)
    return zk, zv


def test_prefill_equals_forward(target):
    t = toks(40)
    h1, logits1 = gpt_forward(target, TARGET_CFG, t)
    h2, _, _, logits2 = gpt_prefill(target, TARGET_CFG, t)
    np.testing.assert_allclose(h1, h2, atol=1e-5)
    np.testing.assert_allclose(logits1, logits2, atol=1e-5)


def test_causality(target):
    """Perturbing a future token must not change past logits."""
    t = toks(24)
    _, l1 = gpt_forward(target, TARGET_CFG, t)
    t2 = t.at[20].set((t[20] + 7) % 120)
    _, l2 = gpt_forward(target, TARGET_CFG, t2)
    np.testing.assert_allclose(l1[:19], l2[:19], atol=1e-6)
    assert float(jnp.abs(l1[20:] - l2[20:]).max()) > 1e-4


def test_incremental_decode_equals_full(target):
    """AR decode (N=1 steps) over a cache == one full forward."""
    plen, extra = 20, 6
    full = toks(plen + extra, seed=3)
    _, kvk, kvv, logits_p = gpt_prefill(target, TARGET_CFG, full[:plen])
    kvk, kvv = pad_cache(kvk, kvv)
    _, logits_full = gpt_forward(target, TARGET_CFG, full)

    for i in range(extra):
        cur = plen + i
        mask = (jnp.arange(S) <= cur)[None, :]
        lg, _, kvk, kvv = gpt_decode(
            target, TARGET_CFG, kvk, kvv, jnp.int32(cur),
            full[cur : cur + 1], jnp.asarray([cur], jnp.int32), mask)
        np.testing.assert_allclose(lg[0], logits_full[cur], atol=1e-4)


def test_tree_decode_each_node_matches_its_path(target):
    """Branching tree: node logits == full forward over the node's path.

    Tree over prefix P (len 12):       root r
                                      /      \\
                                     a        b
                                     |
                                     c
    """
    plen = 12
    prefix = toks(plen, seed=4)
    _, kvk, kvv, _ = gpt_prefill(target, TARGET_CFG, prefix)
    kvk, kvv = pad_cache(kvk, kvv)

    r, a, b, c = 30, 40, 50, 60
    tree_tokens = jnp.asarray([r, a, b, c], jnp.int32)
    # positions: depth below prefix
    positions = jnp.asarray([plen, plen + 1, plen + 1, plen + 2], jnp.int32)
    n = 4
    mask = np.zeros((n, S), bool)
    mask[:, :plen] = True               # all see the committed prefix
    anc = {0: [0], 1: [0, 1], 2: [0, 2], 3: [0, 1, 3]}
    for node, ancestors in anc.items():
        for apos in ancestors:
            mask[node, plen + apos] = True
    lg, _, _, _ = gpt_decode(target, TARGET_CFG, kvk, kvv, jnp.int32(plen),
                             tree_tokens, positions, jnp.asarray(mask))

    paths = {0: [r], 1: [r, a], 2: [r, b], 3: [r, a, c]}
    for node, path in paths.items():
        seq = jnp.concatenate([prefix, jnp.asarray(path, jnp.int32)])
        _, logits_full = gpt_forward(target, TARGET_CFG, seq)
        np.testing.assert_allclose(lg[node], logits_full[-1], atol=1e-4,
                                   err_msg=f"node {node}")


def test_decode_mask_blocks_dead_slots(target):
    """Slots excluded by the mask (rolled-back tree nodes) must not affect
    the result even though their KV rows contain stale data."""
    plen = 10
    prefix = toks(plen, seed=5)
    _, kvk, kvv, _ = gpt_prefill(target, TARGET_CFG, prefix)
    kvk, kvv = pad_cache(kvk, kvv)
    # poison slots plen..plen+4 with garbage KV
    kvk = kvk.at[:, plen : plen + 5].set(99.0)
    kvv = kvv.at[:, plen : plen + 5].set(-99.0)
    cur = plen + 5  # write the new token past the poisoned region
    mask = ((jnp.arange(S) < plen) | (jnp.arange(S) == cur))[None, :]
    lg, _, _, _ = gpt_decode(target, TARGET_CFG, kvk, kvv, jnp.int32(cur),
                             jnp.asarray([44], jnp.int32),
                             jnp.asarray([plen], jnp.int32), mask)
    seq = jnp.concatenate([prefix, jnp.asarray([44], jnp.int32)])
    _, logits_full = gpt_forward(target, TARGET_CFG, seq)
    np.testing.assert_allclose(lg[0], logits_full[-1], atol=1e-4)


def test_draft_chain_decode_matches_training_forward(target, draft):
    """Draft KV-chain decode step-by-step == the full draft training forward
    (context harmony at speculation step 1)."""
    tlen = 16
    t = toks(tlen, seed=6)
    tfeats, _ = gpt_forward(target, TARGET_CFG, t)
    wte = target["wte"]

    g_full, _ = draft_forward(draft, wte, DRAFT_CFG, t, shift_feats(tfeats))

    kvk, kvv, _ = draft_prefill(draft, wte, DRAFT_CFG, t[:8], tfeats[:8])
    zk = jnp.zeros((S, DRAFT_CFG.n_heads, DRAFT_CFG.d_head)).at[:8].set(kvk)
    zv = jnp.zeros((S, DRAFT_CFG.n_heads, DRAFT_CFG.d_head)).at[:8].set(kvv)
    B = 10  # decode block width (padded)
    for i in range(8, tlen):
        mask = np.zeros((B, S), bool)
        mask[0, : i + 1] = True
        tok = jnp.zeros((B,), jnp.int32).at[0].set(t[i])
        fin = jnp.zeros((B, DRAFT_CFG.d_model)).at[0].set(tfeats[i - 1])
        pos = jnp.zeros((B,), jnp.int32).at[0].set(i)
        lg, g, zk, zv = draft_decode(draft, wte, DRAFT_CFG, zk, zv,
                                     jnp.int32(i), tok, fin, pos,
                                     jnp.asarray(mask))
        np.testing.assert_allclose(g[0], g_full[i], atol=1e-4,
                                   err_msg=f"pos {i}")


def test_medusa_shapes(target):
    mp = init_medusa(jax.random.PRNGKey(7))
    feats = jnp.ones((3, TARGET_CFG.d_model))
    out = medusa_apply(mp, target["wte"], feats)
    assert out.shape == (3, 4, TARGET_CFG.vocab)


def test_sps_config_forward():
    sp = init_gpt(jax.random.PRNGKey(8), SPS_CFG)
    t = toks(20, seed=9)
    h, logits = gpt_forward(sp, SPS_CFG, t)
    assert logits.shape == (20, SPS_CFG.vocab)
    assert bool(jnp.isfinite(logits).all())
