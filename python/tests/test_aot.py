"""AOT lowering: every graph lowers to parseable HLO text with the expected
parameter count, and a lowered graph executes identically to the jit original
(round-trip through XlaComputation on the in-process CPU client)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, ckpt
from compile.model import SPS_CFG, TARGET_CFG, gpt_decode, init_gpt


@pytest.fixture(scope="module")
def graphs():
    return aot.build_graphs(decode_ns=(1,), draft_bs=(10,))


def test_all_graphs_lower_to_hlo_text(graphs):
    for name, (fn, arg_specs, pnames, inputs, outputs) in graphs.items():
        lowered = jax.jit(fn).lower(*arg_specs)
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text, name
        # count parameters of the ENTRY computation only (nested fusion
        # computations also contain parameter() instructions); ENTRY is the
        # last computation in the emitted text.
        entry = text[text.index("ENTRY"):]
        n_params = entry.count(" parameter(")
        n_expected = len(pnames) + len(inputs)
        assert n_params == n_expected, (name, n_params, n_expected)


def test_graph_param_order_matches_manifest_order(graphs):
    """The weight-argument order the HLO expects == ckpt manifest order."""
    name = "sps_prefill"
    fn, arg_specs, pnames, inputs, outputs = graphs[name]
    sp = init_gpt(jax.random.PRNGKey(2), SPS_CFG)
    assert pnames == [n for n, _ in ckpt.flatten_named(sp)]


def test_lowering_is_deterministic(graphs):
    """Artifacts must be reproducible byte-for-byte across lowerings
    (otherwise `make artifacts` invalidates compiled caches spuriously)."""
    fn, arg_specs, *_ = graphs["sps_decode_n1"]
    t1 = aot.to_hlo_text(jax.jit(fn).lower(*arg_specs))
    t2 = aot.to_hlo_text(jax.jit(fn).lower(*arg_specs))
    assert t1 == t2


def test_decode_graph_consumes_i32_mask(graphs):
    """Masks cross the boundary as i32 (rust-friendly) and are cast inside;
    the HLO entry must therefore declare an s32[N,512] parameter."""
    fn, arg_specs, pnames, inputs, outputs = graphs["target_decode_n1"]
    text = aot.to_hlo_text(jax.jit(fn).lower(*arg_specs))
    entry = text[text.index("ENTRY"):]
    assert "s32[1,512]" in entry
    # and the jit path matches semantics of a bool mask
    sp = init_gpt(jax.random.PRNGKey(0), TARGET_CFG)
    S = aot.S
    L, H, hd = TARGET_CFG.n_layers, TARGET_CFG.n_heads, TARGET_CFG.d_head
    rng = np.random.default_rng(0)
    kvk = rng.normal(size=(L, S, H, hd)).astype(np.float32)
    kvv = rng.normal(size=(L, S, H, hd)).astype(np.float32)
    mask_i = (np.arange(S) <= 7).astype(np.int32)[None, :].copy()
    got = jax.jit(fn)(sp, kvk, kvv, np.int32(7), np.array([42], np.int32),
                      np.array([7], np.int32), mask_i)
    want = gpt_decode(sp, TARGET_CFG, kvk, kvv, np.int32(7),
                      np.array([42], np.int32), np.array([7], np.int32),
                      mask_i != 0)
    for a, b in zip(jax.tree_util.tree_leaves(got), jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_meta_graph_inventory():
    g = aot.build_graphs()
    names = set(g)
    assert {"target_prefill", "target_decode_n1", "target_decode_n64",
            "target_decode_n128", "draft_prefill", "draft_decode_b10",
            "sps_prefill", "sps_decode_n1", "medusa_heads"} <= names
